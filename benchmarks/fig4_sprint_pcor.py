"""Fig. 4 reproduction: SPRINT ``pcor`` Load + Exec across platforms.

The paper's dataset: 11000 genes × 321 samples, correlation with 2 SPRINT
processes.  Here Load = materializing the expression matrix; Exec = the
correlation.  Under BOINC/V-BOINC platforms, Exec is split into row-strip
work units across 2 volunteer workers (SPRINT's MPI layout) with quorum
validation — the "application with dependencies" running under the
framework.  The Pallas kernel (repro/kernels/pcor) is the TPU target; the
XLA path is timed on this CPU container (kernel validated in tests).
"""
from __future__ import annotations

import hashlib

import numpy as np

from benchmarks.common import CapsulePlatform, csv_line, time_fn
from repro.core.scheduler import SimClock, VolunteerScheduler

GENES, SAMPLES, WORKERS = 11_000, 321, 2


def _load() -> np.ndarray:
    rng = np.random.default_rng(0)
    return rng.standard_normal((GENES, SAMPLES)).astype(np.float32)


def _exec_host(x) -> np.ndarray:
    from repro.kernels.pcor.ops import correlate
    return np.asarray(correlate(x, mode="ref"))


def _exec_workunits(x, capsule=None) -> np.ndarray:
    """Row-strip work units across 2 volunteers (SPRINT pcor layout)."""
    from repro.kernels.pcor.ops import pcor_strip
    sched = VolunteerScheduler(clock=SimClock())
    strip = (GENES + WORKERS - 1) // WORKERS
    for i in range(WORKERS):
        sched.submit(i, {"row_start": i * strip})
    out = np.empty((GENES, GENES), np.float32)
    for w in range(WORKERS):
        wid = f"sprint-{w}"
        sched.join(wid)
        unit = sched.request_work(wid)
        r0 = unit.payload["row_start"]
        rc = min(strip, GENES - r0)
        fn = (lambda: np.asarray(pcor_strip(x, r0, rc))) if capsule is None \
            else (lambda: np.asarray(capsule.run(
                lambda: pcor_strip(x, r0, rc))))
        res = fn()
        out[r0:r0 + rc] = res
        # no-copy blake2b: quorum-validation digest at memory bandwidth
        digest = hashlib.blake2b(
            memoryview(np.ascontiguousarray(res)).cast("B")).hexdigest()
        sched.report(wid, unit.unit_id, digest)
    assert sched.done()
    return out


def run(reps: int = 3) -> list[str]:
    lines = []
    t_load = time_fn(_load, reps=reps)
    x = _load()
    capsule = CapsulePlatform()

    t_host = time_fn(lambda: _exec_host(x), reps=reps)
    t_boinc = time_fn(lambda: _exec_workunits(x), reps=reps)
    t_vm = time_fn(lambda: capsule.run(lambda: _exec_host(x)), reps=reps)
    t_vb = time_fn(lambda: _exec_workunits(x, capsule), reps=reps)

    # correctness cross-check vs numpy
    err = float(np.abs(_exec_workunits(x) - np.corrcoef(x)).max())
    lines += [
        csv_line("fig4.load", t_load.us, f"genes={GENES}x{SAMPLES}"),
        csv_line("fig4.exec.host", t_host.us, "baseline"),
        csv_line("fig4.exec.boinc", t_boinc.us,
                 f"overhead={(t_boinc.mean_s/t_host.mean_s-1)*100:+.1f}%"),
        csv_line("fig4.exec.vm", t_vm.us,
                 f"overhead={(t_vm.mean_s/t_host.mean_s-1)*100:+.1f}%"),
        csv_line("fig4.exec.vboinc", t_vb.us,
                 f"impl_overhead={(t_vb.mean_s/t_vm.mean_s-1)*100:+.1f}%"),
        csv_line("fig4.exec.correctness", 0.0, f"max_err_vs_numpy={err:.1e}"),
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
