"""Replication overhead + failover benchmark.

Measures what the ReplicaSet costs and what it buys:

* ``hot_us``       — snapshot wall time with replication attached (the hot
  path only enqueues refs; compare against ``solo_us``, the same snapshot
  stream into a bare ChunkStore — the gap is the enqueue overhead);
* ``pump_us``      — off-path cost of fanning one round's objects to the
  peers, and ``repl_bytes``, the verified bytes the peers ingested;
* ``failover_us``  — kill-the-primary-with-disk-loss → promote the best
  replica → resolve the latest snapshot end to end, byte-verified.

Workload: a params+optimizer state where a sparse slice mutates per round
(the Table II "memory" class) — the case replication must not slow down.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import csv_line
from repro.core.chunkstore import ChunkStore
from repro.core.replica import ReplicaSet
from repro.core.snapshots import SnapshotManager

CHUNK = 1 << 14


def _state(n: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {"params": rng.standard_normal(n).astype(np.float32),
            "opt_m": np.zeros(n, np.float32)}


def _mutate(state: dict, i: int) -> dict:
    params = state["params"].copy()
    params[i * 37 % params.size] += 1.0          # sparse touch
    m = state["opt_m"].copy()
    m[: m.size // 8] += 0.01                     # optimizer slice churn
    return {"params": params, "opt_m": m}


def run_rows(peers: int = 2, rounds: int = 4, n: int = 1 << 16) -> list[dict]:
    # warm the diff path (lazy kernel/op setup) outside any timed region
    warm = SnapshotManager(ChunkStore(chunk_bytes=CHUNK), keep_last=2)
    warm.snapshot(_state(n), step=0)
    warm.snapshot(_mutate(_state(n), 0), step=1)

    # baseline: same snapshot stream into an unreplicated store
    solo_mgr = SnapshotManager(ChunkStore(chunk_bytes=CHUNK), keep_last=4)
    state = _state(n)
    solo_mgr.snapshot(state, step=0)
    solo_times = []
    s = state
    for i in range(rounds):
        s = _mutate(s, i)
        t0 = time.perf_counter()
        solo_mgr.snapshot(s, step=i + 1)
        solo_times.append(time.perf_counter() - t0)

    # replicated: identical stream through a ReplicaSet
    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(peers + 1)]
    rs = ReplicaSet(stores[0], stores[1:])
    mgr = SnapshotManager(rs, keep_last=4)
    state = _state(n)
    mgr.snapshot(state, step=0)
    rs.flush()
    hot_times, pump_times = [], []
    s = state
    for i in range(rounds):
        s = _mutate(s, i)
        t0 = time.perf_counter()
        mgr.snapshot(s, step=i + 1)              # hot path: enqueue only
        hot_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rs.pump()                                # off-path peer fan-out
        pump_times.append(time.perf_counter() - t0)
    repl_bytes = sum(m.stats["ingest_bytes"] for m in stores[1:])
    report = rs.replication_report(
        mgr.get_manifest(mgr.latest()).all_refs())

    # failover: primary disk loss -> promote -> byte-verified restore
    want = np.concatenate([s["params"].view(np.uint8),
                           s["opt_m"].view(np.uint8)]).tobytes()
    t0 = time.perf_counter()
    rs.mark_down(0)
    stores[0].wipe()
    rs.promote_best()
    got, _ = mgr.restore(target_tree={"params": np.zeros(n, np.float32),
                                      "opt_m": np.zeros(n, np.float32)})
    failover_s = time.perf_counter() - t0
    restored = np.concatenate([got["params"].reshape(-1).view(np.uint8),
                               got["opt_m"].reshape(-1).view(np.uint8)]
                              ).tobytes()
    assert restored == want, "failover restore diverged"

    return [{
        "name": f"x{peers + 1}",
        "solo_us": float(np.mean(solo_times)) * 1e6,
        "hot_us": float(np.mean(hot_times)) * 1e6,
        "pump_us": float(np.mean(pump_times)) * 1e6,
        "repl_bytes": repl_bytes,
        "outbox_dropped": rs.rstats["outbox_dropped"],
        "min_factor": report["min_factor"],
        "failover_us": round(failover_s * 1e6),
    }]


def _format(rows: list[dict]) -> list[str]:
    lines = []
    for r in rows:
        derived = ";".join(f"{k}={r[k]}" for k in (
            "solo_us", "pump_us", "repl_bytes", "outbox_dropped",
            "min_factor", "failover_us"))
        lines.append(csv_line(f"replica.{r['name']}", r["hot_us"], derived))
    return lines


def run(rounds: int = 4) -> list[str]:
    return _format(run_rows(rounds=rounds))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--peers", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--size", type=int, default=1 << 16,
                    help="elements per state tensor")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.peers < 1 or args.rounds < 1:
        ap.error("--peers and --rounds must be >= 1")
    rows = run_rows(args.peers, args.rounds, args.size)
    print("\n".join(_format(rows)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "replica_failover", "peers": args.peers,
                       "rounds": args.rounds, "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
