"""Telemetry-overhead microbenchmark: dispatch p50, tracing off vs on.

The telemetry plane promises that the *disabled* path costs one attribute
check on the scheduler hot path (``core/telemetry.py``), and that even
the *enabled* path (latency histogram + submit/dispatch/lease/report
events per unit) stays within a small constant factor.  This benchmark
pins both claims to numbers CI can gate:

* ``disabled`` row — ``request_work`` p50 with a hub whose tracing flag
  is off (the default for every test and benchmark in the repo).  This
  is the figure the committed ``BENCH_scheduler.json`` flat-ratio gate
  implicitly depends on, so it also gates loosely against the committed
  ``BENCH_telemetry.json`` baseline;
* ``enabled`` row — same workload with ``tracing=True`` on an isolated
  hub (ring-buffer recorder + dispatch-latency histogram live);
* ``overhead_ratio`` — enabled p50 / disabled p50, gated *within* one
  run by ``check_regression.py --kind telemetry`` (default limit 3.0)
  so it is immune to runner speed.

    PYTHONPATH=src:. python -m benchmarks.telemetry_overhead \
        --json /tmp/tel.json
    PYTHONPATH=src:. python -m benchmarks.check_regression /tmp/tel.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_line
from repro.core import telemetry as tlm
from repro.core.scheduler import SimClock, VolunteerScheduler

BURST = 8                 # requests per sampled volunteer


def measure(tracing: bool, clients: int, samples: int,
            seed: int = 0) -> dict:
    """Steady-state ``request_work`` latency against an isolated hub.

    Mirrors ``server_throughput.measure_row``'s duty cycle (burst of
    requests, report each unit untimed) so the two benchmarks measure
    the same regime; the only variable is the hub's ``tracing`` flag."""
    rng = np.random.default_rng(seed)
    tel = tlm.Telemetry(tracing=tracing, clock=SimClock())
    sched = VolunteerScheduler(replication=1, quorum=1, deadline_s=3600.0,
                               clock=SimClock(), telemetry=tel)
    for i in range(clients):
        sched.join(f"v{i}")
    for uid in range(samples * 2 + BURST * 4):
        sched.submit(uid, {"batch_index": uid})
    h = hashlib.sha256(b"result").hexdigest()
    n_bursts = max(1, samples // BURST)
    pick = rng.integers(0, clients, size=n_bursts)
    lat = []
    for i in pick:
        w = f"v{i}"
        for _ in range(BURST):
            t0 = time.perf_counter()
            wu = sched.request_work(w)
            lat.append(time.perf_counter() - t0)
            assert wu is not None, "backlog drained mid-measurement"
            sched.report(w, wu.unit_id, h)      # untimed: keep churn real
    lat = np.asarray(lat)
    return {
        "name": "enabled" if tracing else "disabled",
        "tracing": tracing, "clients": clients, "samples": int(len(lat)),
        "p50_us": float(np.percentile(lat, 50) * 1e6),
        "p99_us": float(np.percentile(lat, 99) * 1e6),
        "events": len(tel.events),
    }


def run_curve(clients: int = 2000, samples: int = 400) -> dict:
    rows = [measure(False, clients, samples),
            measure(True, clients, samples)]
    by = {r["name"]: r for r in rows}
    ratio = (by["enabled"]["p50_us"] / by["disabled"]["p50_us"]
             if by["disabled"]["p50_us"] > 0 else None)
    return {"kind": "telemetry", "clients": clients, "samples": samples,
            "rows": rows, "overhead_ratio": ratio}


def run(tiny: bool = True) -> list[str]:
    """Registry entry point (benchmarks/run.py): CSV lines."""
    curve = run_curve()
    lines = [csv_line(f"telemetry.{r['name']}", r["p50_us"],
                      f"p99_us={r['p99_us']:.1f};events={r['events']}")
             for r in curve["rows"]]
    lines.append(csv_line("telemetry.overhead_ratio", 0.0,
                          f"enabled_p50/disabled_p50="
                          f"{curve['overhead_ratio']:.2f}"))
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=2000)
    ap.add_argument("--samples", type=int, default=400)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable result here")
    args = ap.parse_args(argv)
    curve = run_curve(clients=args.clients, samples=args.samples)
    for r in curve["rows"]:
        print(f"  {r['name']:9s} p50 {r['p50_us']:8.2f}us  "
              f"p99 {r['p99_us']:8.2f}us  events {r['events']}")
    print(f"  overhead_ratio enabled/disabled = "
          f"{curve['overhead_ratio']:.2f}")
    if args.json:
        Path(args.json).write_text(json.dumps(curve, indent=2))
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
