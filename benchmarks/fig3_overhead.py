"""Fig. 3 reproduction: six workloads × four platforms.

Paper claims validated:
  (a) BOINC overhead over Host is negligible (scheduler path ≈ host);
  (b) V-BOINC is slower than BOINC only through *virtualization* (capsule)
      — the V-BOINC implementation itself adds negligible overhead
      (compare VM vs V-BOINC);
  (c) the cost is workload-dependent.
Our capsule's "virtualization" is integrity hashing + control-plane
bookkeeping, so (1)≈(2)≈(3)≈(4) is the expected *healthy* outcome here; the
paper's large VM gap was VirtualBox's cost, which XLA does not pay.
"""
from __future__ import annotations

from benchmarks.common import (CapsulePlatform, csv_line, make_workloads,
                               run_boinc, run_host, run_vboinc, run_vm,
                               time_fn)


def run(reps: int = 5, scale: float = 1.0) -> list[str]:
    wl = make_workloads(scale)
    capsule = CapsulePlatform()
    lines = []
    for name, fn in wl.items():
        t_host = time_fn(lambda: run_host(fn), reps=reps)
        t_boinc = time_fn(lambda: run_boinc(fn), reps=reps)
        t_vm = time_fn(lambda: run_vm(fn, capsule), reps=reps)
        t_vb = time_fn(lambda: run_vboinc(fn, capsule), reps=reps)
        boinc_ov = (t_boinc.mean_s / t_host.mean_s - 1) * 100
        impl_ov = (t_vb.mean_s / t_vm.mean_s - 1) * 100
        virt_ov = (t_vm.mean_s / t_host.mean_s - 1) * 100
        lines += [
            csv_line(f"fig3.{name}.host", t_host.us, "baseline"),
            csv_line(f"fig3.{name}.boinc", t_boinc.us,
                     f"boinc_overhead={boinc_ov:+.1f}%"),
            csv_line(f"fig3.{name}.vm", t_vm.us,
                     f"virt_overhead={virt_ov:+.1f}%"),
            csv_line(f"fig3.{name}.vboinc", t_vb.us,
                     f"impl_overhead={impl_ov:+.1f}%"),
        ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
