"""Perf-trajectory gate for the snapshot stall benchmark.

Compares a fresh ``table2_snapshots --json`` run against the committed
baseline (``benchmarks/BENCH_table2.json``) and fails when the trainer's
per-round ``stall_ms`` regresses by more than ``--tolerance`` (default
25%).  A small absolute floor (``--floor-ms``) keeps shared-runner noise
from failing rows whose stall is near zero — a 1 ms → 1.4 ms wobble is
jitter, a 10 ms → 14 ms jump is a regression.

Only the write-heavy rows gate by default: ``cpu``/``primes`` snapshot an
unchanged state, so their stall is pure probe overhead at microsecond
scale and 25% of it is below timer noise.

    PYTHONPATH=src:. python -m benchmarks.table2_snapshots \
        --tiny --rounds 3 --json /tmp/now.json
    PYTHONPATH=src:. python -m benchmarks.check_regression /tmp/now.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).parent / "BENCH_table2.json"

# rows where the stall is real work being hidden (the zero-stall claim);
# frozen workloads stall for ~nothing in both modes and only add noise
GATED_ROWS = ("memory", "io", "disk", "sprint")


def check(current: dict, baseline: dict, tolerance: float,
          floor_ms: float, rows=GATED_ROWS) -> list[str]:
    """-> list of human-readable failures (empty = pass)."""
    cur = {r["name"]: r for r in current["rows"]}
    base = {r["name"]: r for r in baseline["rows"]}
    failures = []
    for name in rows:
        if name not in base:
            continue                  # baseline predates this workload
        if name not in cur:
            failures.append(f"{name}: row missing from current run")
            continue
        b = float(base[name]["stall_ms"])
        c = float(cur[name]["stall_ms"])
        limit = b * (1.0 + tolerance) + floor_ms
        verdict = "FAIL" if c > limit else "ok"
        print(f"  {name:8s} stall_ms {b:8.3f} -> {c:8.3f}  "
              f"(limit {limit:.3f})  {verdict}")
        if c > limit:
            failures.append(f"{name}: stall_ms {c:.3f} > limit {limit:.3f} "
                            f"(baseline {b:.3f} +{tolerance:.0%} "
                            f"+{floor_ms}ms)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON from table2_snapshots --json")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative stall_ms growth (0.25 = +25%%)")
    ap.add_argument("--floor-ms", type=float, default=2.0,
                    help="absolute slack added to every limit (timer noise)")
    args = ap.parse_args(argv)
    current = json.loads(Path(args.current).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    print(f"stall regression gate (tolerance +{args.tolerance:.0%}, "
          f"floor {args.floor_ms}ms):")
    failures = check(current, baseline, args.tolerance, args.floor_ms)
    if failures:
        print("\n".join(f"REGRESSION: {f}" for f in failures),
              file=sys.stderr)
        return 1
    print("stall within budget on all gated rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
