"""Perf-trajectory gate for the benchmark JSON outputs.

Dispatches on the ``kind`` field of the current-run JSON:

* **snapshot stall** (no ``kind``, from ``table2_snapshots --json``) —
  compares against ``benchmarks/BENCH_table2.json`` and fails when the
  trainer's per-round ``stall_ms`` regresses by more than ``--tolerance``
  (default 25%).  A small absolute floor (``--floor-ms``) keeps
  shared-runner noise from failing rows whose stall is near zero — a
  1 ms → 1.4 ms wobble is jitter, a 10 ms → 14 ms jump is a regression.
  Only the write-heavy rows gate by default: ``cpu``/``primes`` snapshot
  an unchanged state, so their stall is pure probe overhead at
  microsecond scale and 25% of it is below timer noise.

* **telemetry** (``kind: "telemetry"``, from ``telemetry_overhead
  --json``) — compares against ``benchmarks/BENCH_telemetry.json``.  The
  load-bearing check is ``overhead_ratio``: tracing-enabled dispatch p50
  must stay within ``--overhead-limit`` (default 3.0) of tracing-disabled,
  computed *within* one run.  The disabled-path p50 also gates loosely
  against the baseline (doubled tolerance + ``--floor-us``) — that row is
  what the scheduler flat-ratio gate implicitly rides on.

* **scheduler** (``kind: "scheduler"``, from ``server_throughput
  --json``) — compares against ``benchmarks/BENCH_scheduler.json``.  The
  load-bearing check is ``flat_ratio``: p50 dispatch at the largest
  fleet/shard row must stay within ``--flat-limit`` (default 2.0) of the
  smallest — the O(1)-dispatch claim, computed *within* one run so it is
  immune to runner speed.  Per-row p50s also gate against the baseline,
  but loosely (``--tolerance`` doubled + ``--floor-us``): absolute
  microsecond timings vary wildly across shared runners.

* **edge** (``kind: "edge"``, from ``edge_egress --json``) — compares
  against ``benchmarks/BENCH_edge.json``.  All three checks are computed
  *within* one run: ``egress_reduction`` (primary egress no-cache /
  with-cache) must stay ≥ ``--egress-factor`` (default 5.0), every cached
  restore must be byte-identical to the origin, and the churn cycle must
  be route-deterministic across seeds.

An unknown ``kind`` is an error (exit 2), never a silent pass — a typo'd
or future benchmark must not sail through a gate that checked nothing.

    PYTHONPATH=src:. python -m benchmarks.table2_snapshots \
        --tiny --rounds 3 --json /tmp/now.json
    PYTHONPATH=src:. python -m benchmarks.check_regression /tmp/now.json

    PYTHONPATH=src:. python -m benchmarks.server_throughput \
        --tiny --json /tmp/sched.json
    PYTHONPATH=src:. python -m benchmarks.check_regression /tmp/sched.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).parent / "BENCH_table2.json"
SCHED_BASELINE = Path(__file__).parent / "BENCH_scheduler.json"
TELEMETRY_BASELINE = Path(__file__).parent / "BENCH_telemetry.json"
EDGE_BASELINE = Path(__file__).parent / "BENCH_edge.json"

# every kind this gate understands ("stall" is the implicit default for
# historical table2 JSON without a kind field); anything else is an error,
# never a silent pass
KNOWN_KINDS = ("stall", "scheduler", "telemetry", "edge")

# rows where the stall is real work being hidden (the zero-stall claim);
# frozen workloads stall for ~nothing in both modes and only add noise
GATED_ROWS = ("memory", "io", "disk", "sprint")


def check(current: dict, baseline: dict, tolerance: float,
          floor_ms: float, rows=GATED_ROWS) -> list[str]:
    """-> list of human-readable failures (empty = pass)."""
    cur = {r["name"]: r for r in current["rows"]}
    base = {r["name"]: r for r in baseline["rows"]}
    failures = []
    for name in rows:
        if name not in base:
            continue                  # baseline predates this workload
        if name not in cur:
            failures.append(f"{name}: row missing from current run")
            continue
        b = float(base[name]["stall_ms"])
        c = float(cur[name]["stall_ms"])
        limit = b * (1.0 + tolerance) + floor_ms
        verdict = "FAIL" if c > limit else "ok"
        print(f"  {name:8s} stall_ms {b:8.3f} -> {c:8.3f}  "
              f"(limit {limit:.3f})  {verdict}")
        if c > limit:
            failures.append(f"{name}: stall_ms {c:.3f} > limit {limit:.3f} "
                            f"(baseline {b:.3f} +{tolerance:.0%} "
                            f"+{floor_ms}ms)")
    return failures


def check_scheduler(current: dict, baseline: dict, tolerance: float,
                    floor_us: float, flat_limit: float) -> list[str]:
    """-> list of human-readable failures (empty = pass)."""
    failures = []
    fr = current.get("flat_ratio")
    if fr is None:
        failures.append("flat_ratio missing (gate rows absent from run)")
    else:
        gate = current.get("gate", ["?", "?"])
        verdict = "FAIL" if fr > flat_limit else "ok"
        print(f"  flat_ratio {gate[1]}/{gate[0]} = {fr:.2f}  "
              f"(limit {flat_limit:.2f})  {verdict}")
        if fr > flat_limit:
            failures.append(f"flat_ratio {fr:.2f} > {flat_limit:.2f}: "
                            f"dispatch is no longer flat in fleet size")
    cur = {r["name"]: r for r in current["rows"]}
    base = {r["name"]: r for r in baseline["rows"]}
    for name, b in base.items():
        if name not in cur:
            failures.append(f"{name}: row missing from current run")
            continue
        bv, cv = float(b["p50_us"]), float(cur[name]["p50_us"])
        limit = bv * (1.0 + 2.0 * tolerance) + floor_us
        verdict = "FAIL" if cv > limit else "ok"
        print(f"  {name:16s} p50_us {bv:8.2f} -> {cv:8.2f}  "
              f"(limit {limit:.2f})  {verdict}")
        if cv > limit:
            failures.append(f"{name}: p50_us {cv:.2f} > limit {limit:.2f} "
                            f"(baseline {bv:.2f})")
    # elastic-membership gate: splitting a hot shard under load must
    # leave the volunteer dispatch path flat (same bound as fleet size)
    rb = current.get("rebalance")
    if rb is None:
        if "rebalance" in baseline:
            failures.append("rebalance row missing from current run")
    else:
        ratio = rb.get("ratio")
        if ratio is None:
            failures.append("rebalance ratio missing from run")
        else:
            verdict = "FAIL" if ratio > flat_limit else "ok"
            print(f"  rebalance p50 {rb['p50_before_us']:.2f} -> "
                  f"{rb['p50_after_us']:.2f}  ratio {ratio:.2f}  "
                  f"(limit {flat_limit:.2f})  {verdict}")
            if ratio > flat_limit:
                failures.append(
                    f"rebalance ratio {ratio:.2f} > {flat_limit:.2f}: "
                    f"splitting a shard degrades the dispatch path")
    return failures


def check_telemetry(current: dict, baseline: dict, tolerance: float,
                    floor_us: float, overhead_limit: float) -> list[str]:
    """-> list of human-readable failures (empty = pass)."""
    failures = []
    ratio = current.get("overhead_ratio")
    if ratio is None:
        failures.append("overhead_ratio missing from run")
    else:
        verdict = "FAIL" if ratio > overhead_limit else "ok"
        print(f"  overhead_ratio enabled/disabled = {ratio:.2f}  "
              f"(limit {overhead_limit:.2f})  {verdict}")
        if ratio > overhead_limit:
            failures.append(f"overhead_ratio {ratio:.2f} > "
                            f"{overhead_limit:.2f}: tracing is no longer "
                            f"cheap on the dispatch hot path")
    cur = {r["name"]: r for r in current["rows"]}
    base = {r["name"]: r for r in baseline["rows"]}
    # only the disabled path gates vs the baseline: it is the default
    # configuration every other benchmark (and the flat-ratio gate) runs in
    for name in ("disabled",):
        if name not in base:
            continue
        if name not in cur:
            failures.append(f"{name}: row missing from current run")
            continue
        bv, cv = float(base[name]["p50_us"]), float(cur[name]["p50_us"])
        limit = bv * (1.0 + 2.0 * tolerance) + floor_us
        verdict = "FAIL" if cv > limit else "ok"
        print(f"  {name:9s} p50_us {bv:8.2f} -> {cv:8.2f}  "
              f"(limit {limit:.2f})  {verdict}")
        if cv > limit:
            failures.append(f"{name}: p50_us {cv:.2f} > limit {limit:.2f} "
                            f"(baseline {bv:.2f})")
    return failures


def check_edge(current: dict, baseline: dict,
               egress_factor: float) -> list[str]:
    """-> list of human-readable failures (empty = pass).

    The load-bearing checks are computed *within* one run, so they are
    immune to runner speed: primary egress with caches must stay at least
    ``egress_factor`` below the no-cache baseline, every restore must be
    byte-identical, and the kill → re-discover → demand-fill cycle must be
    deterministic across the run's churn seeds."""
    failures = []
    er = current.get("egress_reduction")
    if er is None:
        failures.append("egress_reduction missing from run")
    else:
        verdict = "FAIL" if er < egress_factor else "ok"
        print(f"  egress_reduction baseline/edge = {er:.2f}x  "
              f"(need >= {egress_factor:.2f}x)  {verdict}")
        if er < egress_factor:
            failures.append(f"egress_reduction {er:.2f}x < "
                            f"{egress_factor:.2f}x: the cache tier no "
                            f"longer absorbs the re-attach wave")
    for flag, msg in (("byte_identical",
                       "a cached restore diverged from the origin bytes"),
                      ("deterministic",
                       "same-seed churn runs picked different routes")):
        val = current.get(flag)
        verdict = "FAIL" if val is not True else "ok"
        print(f"  {flag} = {val}  {verdict}")
        if val is not True:
            failures.append(f"{flag}: {msg}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON from table2_snapshots --json or "
                                    "server_throughput --json")
    ap.add_argument("--baseline", default=None,
                    help="defaults to the committed baseline matching the "
                         "current run's kind")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative stall_ms growth (0.25 = +25%%)")
    ap.add_argument("--floor-ms", type=float, default=2.0,
                    help="absolute slack added to every limit (timer noise)")
    ap.add_argument("--floor-us", type=float, default=100.0,
                    help="absolute per-row slack for scheduler p50 gating")
    ap.add_argument("--flat-limit", type=float, default=2.0,
                    help="max allowed scheduler flat_ratio (O(1) dispatch)")
    ap.add_argument("--overhead-limit", type=float, default=3.0,
                    help="max allowed telemetry enabled/disabled p50 ratio")
    ap.add_argument("--egress-factor", type=float, default=5.0,
                    help="min required primary-egress reduction (edge kind)")
    args = ap.parse_args(argv)
    current = json.loads(Path(args.current).read_text())
    kind = current.get("kind", "stall")
    if kind not in KNOWN_KINDS:
        print(f"check_regression: unknown kind {kind!r} in {args.current} "
              f"(known: {', '.join(KNOWN_KINDS)}) — refusing to pass a "
              f"gate it cannot check", file=sys.stderr)
        return 2
    default_base = {"scheduler": SCHED_BASELINE,
                    "telemetry": TELEMETRY_BASELINE,
                    "edge": EDGE_BASELINE}.get(kind, BASELINE)
    baseline = json.loads(Path(args.baseline or default_base).read_text())
    if kind == "edge":
        print(f"edge egress gate (egress_factor "
              f">={args.egress_factor:.2f}x):")
        failures = check_edge(current, baseline, args.egress_factor)
    elif kind == "telemetry":
        print(f"telemetry overhead gate (overhead_limit "
              f"{args.overhead_limit:.2f}, tolerance "
              f"+{2 * args.tolerance:.0%}, floor {args.floor_us}us):")
        failures = check_telemetry(current, baseline, args.tolerance,
                                   args.floor_us, args.overhead_limit)
    elif kind == "scheduler":
        print(f"scheduler dispatch gate (flat_limit {args.flat_limit:.2f}, "
              f"tolerance +{2 * args.tolerance:.0%}, "
              f"floor {args.floor_us}us):")
        failures = check_scheduler(current, baseline, args.tolerance,
                                   args.floor_us, args.flat_limit)
    else:
        print(f"stall regression gate (tolerance +{args.tolerance:.0%}, "
              f"floor {args.floor_ms}ms):")
        failures = check(current, baseline, args.tolerance, args.floor_ms)
    if failures:
        print("\n".join(f"REGRESSION: {f}" for f in failures),
              file=sys.stderr)
        return 1
    print("within budget on all gated rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
