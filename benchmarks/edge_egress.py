"""Edge-distribution benchmark: primary egress vs volunteer count.

The paper's server ships every capsule itself, so its egress grows
linearly with volunteer count (207 MB × N at 9 Mbps in the paper's own
measurement).  With the ``EdgeTier`` in front, a cold re-attach wave
drains from the delta caches instead: the primary pays roughly one
capsule per cache (prefetch + demand-fill), not one per volunteer.

Measured per volunteer-count row:

* ``baseline_egress``  — origin bytes sent with no caches (every
  volunteer downloads its full plan from the primary);
* ``edge_egress``      — origin bytes sent with the cache tier attached
  (prefetch of the hot base + demand-fills only);
* ``cache_egress``     — bytes the caches served in the origin's stead;
* ``agg_mbps``         — aggregate fetch bandwidth through the tier.

The JSON gate (``check_regression.py --egress-factor``, kind ``edge``)
rides on three within-run facts: ``egress_reduction`` (baseline/edge at
the largest row), ``byte_identical`` (every sampled cached restore
resolves to exactly the origin bytes), and ``deterministic`` (the
kill → re-discover → stale-revive → demand-fill churn cycle picks the
same routes when replayed, under 3 ``ChurnSim`` seeds).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import csv_line
from repro.core.chunkstore import ChunkStore
from repro.core.edge import EdgeCache, EdgeTier
from repro.core.sim import ChurnSim

CHUNK = 1 << 14
CHURN_SEEDS = (7, 19, 42)


def _build_origin(chunks: int, seed: int = 0) -> tuple[ChunkStore, list]:
    """Origin store holding one capsule: raw base chunks plus a short
    delta chain on top (the shape a re-attach wave actually fetches)."""
    rng = np.random.default_rng(seed)
    store = ChunkStore(chunk_bytes=CHUNK)
    base = rng.integers(0, 256, size=chunks * CHUNK, dtype=np.uint8)
    refs = store.put_buffer(memoryview(base))
    # a few mutated blocks become delta records against the base
    for i in range(min(4, len(refs))):
        xor = np.zeros(CHUNK, np.uint8)
        xor[i * 11 % CHUNK] = 1 + i
        refs[i] = store.put_delta(refs[i], xor.tobytes())
    return store, refs


def _fresh_tier(origin: ChunkStore, refs: list, caches: int,
                prefetch: bool = True) -> EdgeTier:
    tier = EdgeTier(origin, [EdgeCache(f"edge-{i}") for i in range(caches)])
    if prefetch:
        tier.prefetch(refs, base_only=True)
    return tier


def _verify_restore(origin: ChunkStore, client: ChunkStore,
                    refs: list) -> bool:
    return client.resolve_buffer(refs) == origin.resolve_buffer(refs)


def run_rows(volunteer_counts, caches: int, chunks: int) -> list[dict]:
    rows = []
    for volunteers in volunteer_counts:
        # baseline: every cold volunteer drains from the primary
        origin, refs = _build_origin(chunks)
        e0 = origin.stats["egress_bytes"]
        for _ in range(volunteers):
            plan = origin.plan_send(refs, set())
            origin.send(plan.refs)
        baseline_egress = origin.stats["egress_bytes"] - e0

        # edge: same wave through discovery + caches (fresh origin so the
        # egress meter starts clean)
        origin, refs = _build_origin(chunks)
        tier = _fresh_tier(origin, refs, caches)
        byte_identical = True
        served = 0
        t0 = time.perf_counter()
        for v in range(volunteers):
            # sample the byte-identical check: full recv + resolve on the
            # first/last volunteer, accounting-only in between
            if v in (0, volunteers - 1):
                client = ChunkStore(chunk_bytes=CHUNK)
                res = tier.fetch(refs, set(), client_store=client)
                byte_identical &= _verify_restore(origin, client, refs)
            else:
                res = tier.fetch(refs, set())
            served += res.bytes_moved
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"v{volunteers}",
            "volunteers": volunteers,
            "caches": caches,
            "baseline_egress": int(baseline_egress),
            "edge_egress": int(tier.stats["origin_egress_bytes"]),
            "cache_egress": int(tier.stats["cache_egress_bytes"]),
            "hits": int(tier.stats["hits"]),
            "misses": int(tier.stats["misses"]),
            "agg_mbps": round(served / max(wall, 1e-9) / 1e6, 1),
            "byte_identical": bool(byte_identical),
        })
    return rows


def churn_routes(seed: int, caches: int, chunks: int) -> list[str]:
    """One kill → re-discover → stale-revive → demand-fill cycle; returns
    the route sequence (who served each fetch)."""
    origin, refs = _build_origin(chunks)
    tier = _fresh_tier(origin, refs, caches)
    sim = ChurnSim(seed=seed, edges=tier)
    routes = [tier.fetch(refs, set()).route]          # warm: cache hit
    killed = sim.random_cache_kill()
    routes.append(tier.fetch(refs, set()).route)      # re-discover survivor
    # stale revive: the killed cache comes back empty while every other
    # cache goes down — it must demand-fill before it can serve
    sim.revive_cache(killed, stale=True)
    for i in tier.alive_indices():
        if i != killed:
            sim.kill_cache(i)
    routes.append(tier.fetch(refs, set()).route)      # demand-fill + serve
    assert tier.members[killed].can_serve(
        origin.plan_send(refs, set()).refs), "stale cache did not fill"
    return routes


def check_determinism(caches: int, chunks: int) -> bool:
    """Replay each seed's churn cycle twice: byte-identical route picks."""
    return all(churn_routes(s, caches, chunks)
               == churn_routes(s, caches, chunks) for s in CHURN_SEEDS)


def _format(rows: list[dict]) -> list[str]:
    lines = []
    for r in rows:
        reduction = r["baseline_egress"] / max(r["edge_egress"], 1)
        derived = ";".join([
            f"baseline_egress={r['baseline_egress']}",
            f"cache_egress={r['cache_egress']}",
            f"reduction={reduction:.1f}x",
            f"hits={r['hits']}", f"misses={r['misses']}",
            f"agg_mbps={r['agg_mbps']}",
        ])
        lines.append(csv_line(f"edge.{r['name']}", r["edge_egress"],
                              derived))
    return lines


def run(tiny: bool = True) -> list[str]:
    counts, caches, chunks = ((10, 20), 2, 8) if tiny else ((25, 100), 3, 32)
    return _format(run_rows(counts, caches, chunks))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run: fewer volunteers, smaller capsule")
    ap.add_argument("--caches", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    counts, caches, chunks = (((10, 20), 2, 8) if args.tiny
                              else ((25, 100), 3, 32))
    if args.caches is not None:
        if args.caches < 1:
            ap.error("--caches must be >= 1")
        caches = args.caches
    rows = run_rows(counts, caches, chunks)
    deterministic = check_determinism(caches, chunks)
    last = rows[-1]
    reduction = last["baseline_egress"] / max(last["edge_egress"], 1)
    print("\n".join(_format(rows)))
    print(f"# egress_reduction={reduction:.1f}x "
          f"deterministic={deterministic}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "edge_egress", "kind": "edge",
                       "caches": caches, "rows": rows,
                       "egress_reduction": round(reduction, 2),
                       "byte_identical": all(r["byte_identical"]
                                             for r in rows),
                       "deterministic": deterministic}, f, indent=2)


if __name__ == "__main__":
    main()
