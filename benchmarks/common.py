"""Shared benchmark utilities: timing, workloads, platform harnesses.

The paper's four execution platforms (Fig. 3) map to:
  host    — the workload called directly;
  boinc   — through the volunteer scheduler (work unit + lease + validate);
  vm      — inside a booted capsule runtime (control plane + integrity hash);
  vboinc  — capsule + scheduler + periodic differencing snapshots.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


@dataclass
class Timing:
    name: str
    mean_s: float
    std_s: float
    reps: int

    @property
    def us(self) -> float:
        return self.mean_s * 1e6


def time_fn(fn: Callable[[], object], *, reps: int = 5,
            warmup: int = 1) -> Timing:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return Timing(getattr(fn, "__name__", "fn"),
                  float(np.mean(ts)), float(np.std(ts)), reps)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# The six Fig-3 workload analogues (jax/numpy-native, CPU-scaled)
# ---------------------------------------------------------------------------
def make_workloads(scale: float = 1.0):
    import jax
    import jax.numpy as jnp

    n = int(512 * scale)
    big = int(4e6 * scale)

    @jax.jit
    def _mm(x):
        for _ in range(4):
            x = jnp.tanh(x @ x)
        return x

    x0 = jnp.asarray(np.random.default_rng(0)
                     .standard_normal((n, n)).astype(np.float32))

    def cpu():                       # compute-bound (paper: Stress CPU)
        return np.asarray(_mm(x0)).sum()

    @jax.jit
    def _sieve(v):
        i = jnp.arange(v.shape[0])
        return jnp.sum(jnp.where(i % 7 != 0, v, 0) ** 2)

    v0 = jnp.arange(big, dtype=jnp.float32)

    def primes():                    # the paper's Primes benchmark
        return np.asarray(_sieve(v0))

    def memory():                    # bandwidth-bound (Stress Memory)
        a = np.random.default_rng(1).standard_normal(big).astype(np.float32)
        for _ in range(4):
            a = a[::-1].copy()
        return a.sum()

    def io():                        # host<->device churn (Stress I/O)
        a = np.ones(big // 2, np.float32)
        for _ in range(4):
            d = jnp.asarray(a)
            a = np.asarray(d) + 1
        return a[0]

    import tempfile
    from pathlib import Path
    tmp = Path(tempfile.mkdtemp(prefix="repro-bench-"))

    def disk():                      # disk-bound (Stress Disk)
        p = tmp / "blob.bin"
        a = np.ones(big, np.float32)
        a.tofile(p)
        b = np.fromfile(p, np.float32)
        return b[-1]

    def create5gb():                 # paper Create5GB via dd (scaled)
        p = tmp / "dd.bin"
        with open(p, "wb") as f:
            f.write(b"\0" * (big * 4))
        return p.stat().st_size

    return {"cpu": cpu, "primes": primes, "memory": memory,
            "io": io, "disk": disk, "create5gb": create5gb}


# ---------------------------------------------------------------------------
# Platform harnesses
# ---------------------------------------------------------------------------
def run_host(fn) -> None:
    fn()


def run_boinc(fn, sched=None) -> None:
    """Workload as a validated work unit through the scheduler."""
    import hashlib

    from repro.core.scheduler import SimClock, VolunteerScheduler
    sched = sched or VolunteerScheduler(clock=SimClock())
    sched.join("local")
    uid = len(sched.units)
    sched.submit(uid, {"fn": getattr(fn, "__name__", "wl")})
    unit = sched.request_work("local")
    result = fn()
    h = hashlib.sha256(repr(result).encode()).hexdigest()
    assert sched.report("local", unit.unit_id, h)


class CapsulePlatform:
    """A booted capsule runtime hosting arbitrary workloads ("VM")."""

    def __init__(self, snapshot_state: Optional[Callable] = None):
        from repro.core.control import CapsuleRuntime, HostSupervisor
        self._snap_state = snapshot_state
        self.runtime = CapsuleRuntime("bench-capsule",
                                      on_snapshot=self._snapshot)
        self.sup = HostSupervisor("bench-host", self.runtime)
        self.sup.control_vm("startvm")
        self.snapshots = None
        self.store = None

    def attach_snapshots(self, keep_last: int = 3):
        from repro.core.chunkstore import ChunkStore
        from repro.core.snapshots import SnapshotManager
        self.store = ChunkStore()
        self.snapshots = SnapshotManager(self.store, keep_last=keep_last)
        return self.snapshots

    def _snapshot(self):
        if self.snapshots is not None and self._snap_state is not None:
            return self.snapshots.snapshot(self._snap_state(), step=0)
        return None

    def run(self, fn) -> object:
        import hashlib
        assert self.runtime.accepting_work
        result = fn()
        # integrity hash of results before upload (sandbox/trust analogue)
        hashlib.sha256(repr(result).encode()).hexdigest()
        self.runtime.heartbeat()
        return result


def run_vm(fn, capsule: Optional[CapsulePlatform] = None) -> None:
    (capsule or CapsulePlatform()).run(fn)


def run_vboinc(fn, capsule: CapsulePlatform, sched=None,
               snapshot_every: bool = False) -> None:
    """Capsule + scheduler (+ optional snapshot after the unit)."""
    run_boinc(lambda: capsule.run(fn), sched)
    if snapshot_every and capsule.snapshots is not None:
        capsule.sup.control_vm("snapshot")
