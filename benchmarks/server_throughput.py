"""§IV-C reproduction: server task-distribution capacity, as a scaling curve.

Anderson et al. measured ~8.8 M tasks/day for a BOINC server on one cheap
machine.  This benchmark measures per-request dispatch latency of the
sharded scheduler plane (``core/shardplane.py``) as the registered fleet
grows 10k → 1M volunteers, and derives tasks/day per row.  The claim under
test: dispatch is O(1) in fleet size — the p50 at 16 shards / 100k clients
stays within 2x of 1 shard / 10k clients (``flat_ratio``, gated in CI by
``check_regression.py`` against ``BENCH_scheduler.json``).

Rows time ``request_work`` alone (the volunteer-facing hot path; watermark
refills amortize inside it), then report each leased unit back untimed so
quorum batching and completion churn stay in the measured regime.  The
capsule-transfer row survives from the original benchmark: the paper
predicts V-BOINC capacity is network-bound (images vs task files), so the
bandwidth side stays visible next to the scheduler curve.

    PYTHONPATH=src:. python -m benchmarks.server_throughput --tiny \
        --json /tmp/sched.json
    PYTHONPATH=src:. python -m benchmarks.check_regression /tmp/sched.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_line, time_fn
from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import SimClock
from repro.core.server import Project, VBoincServer
from repro.core.shardplane import ShardedScheduler
from repro.models.lm import RunConfig

PAPER_TASKS_PER_DAY = 8.8e6

# (clients, shards) rows; the first and last FULL_GATE rows define the
# flat-dispatch ratio the CI gate holds at <= 2x
TINY_ROWS = [(10_000, 1), (20_000, 4), (100_000, 16)]
FULL_ROWS = [(10_000, 1), (50_000, 4), (100_000, 8), (100_000, 16),
             (1_000_000, 16)]
GATE = ((10_000, 1), (100_000, 16))


def _row_name(clients: int, shards: int) -> str:
    return f"c{clients}_s{shards}"


BURST = 8                # requests per sampled volunteer == refill_batch


def measure_row(clients: int, shards: int, samples: int,
                seed: int = 0) -> dict:
    """Register ``clients`` volunteers, keep a deep open backlog, and
    sample steady-state ``request_work`` latency.

    Each sampled volunteer makes a burst of ``refill_batch`` requests
    (one amortized refill scan + queue pops — the plane's designed duty
    cycle), reports every unit, and the plane's report buffer is flushed
    between bursts.  That keeps leases from piling up at the head of the
    pending index, so the row measures the sustained regime rather than
    a fleet of one-shot volunteers abandoning nine of every ten leases."""
    rng = np.random.default_rng(seed)
    plane = ShardedScheduler(shards=shards, replication=1, quorum=1,
                             deadline_s=3600.0, watermark=1,
                             refill_batch=BURST, clock=SimClock())
    for i in range(clients):
        plane.join(f"v{i}")
    # backlog deep enough that no shard ever runs dry mid-measurement
    n_bursts = max(1, samples // BURST)
    for uid in range(samples * 2 + BURST * shards * 4):
        plane.submit(uid, {"batch_index": uid})
    h = hashlib.sha256(b"result").hexdigest()
    pick = rng.integers(0, clients, size=n_bursts)
    lat = []
    t_row0 = time.perf_counter()
    for i in pick:
        w = f"v{i}"
        for _ in range(BURST):
            t0 = time.perf_counter()
            wu = plane.request_work(w)
            lat.append(time.perf_counter() - t0)
            assert wu is not None, "backlog drained mid-measurement"
            plane.report(w, wu.unit_id, h)      # untimed: keep churn real
        plane.flush_reports()                   # server-side validation
    wall = time.perf_counter() - t_row0
    lat = np.asarray(lat)
    per_day = len(lat) * 86_400.0 / wall        # full request+report cycle
    return {
        "name": _row_name(clients, shards),
        "clients": clients, "shards": shards, "samples": int(len(lat)),
        "p50_us": float(np.percentile(lat, 50) * 1e6),
        "p99_us": float(np.percentile(lat, 99) * 1e6),
        "tasks_per_day": per_day,
    }


def measure_rebalance(clients: int = 20_000, shards: int = 4,
                      samples: int = 300, seed: int = 0) -> dict:
    """Split a hot shard *under load* and show dispatch stays flat.

    Builds the same sustained regime as ``measure_row``, samples p50
    before, runs ``split_shard`` on the hottest shard (timing the
    handoff itself), then samples p50 after.  The CI gate holds
    ``p50_after / p50_before`` within the same 2x bound as the scaling
    curve: elastic membership must not cost the volunteer hot path."""
    rng = np.random.default_rng(seed)
    plane = ShardedScheduler(shards=shards, replication=1, quorum=1,
                             deadline_s=3600.0, watermark=1,
                             refill_batch=BURST, clock=SimClock())
    for i in range(clients):
        plane.join(f"v{i}")
    for uid in range(samples * 4 + BURST * shards * 8):
        plane.submit(uid, {"batch_index": uid})
    h = hashlib.sha256(b"result").hexdigest()

    def sample_p50(n_bursts: int) -> float:
        lat = []
        for i in rng.integers(0, clients, size=n_bursts):
            w = f"v{i}"
            for _ in range(BURST):
                t0 = time.perf_counter()
                wu = plane.request_work(w)
                lat.append(time.perf_counter() - t0)
                assert wu is not None, "backlog drained mid-measurement"
                plane.report(w, wu.unit_id, h)
            plane.flush_reports()
        return float(np.percentile(np.asarray(lat), 50) * 1e6)

    n_bursts = max(1, samples // BURST)
    p50_before = sample_p50(n_bursts)
    alive = plane.alive_shards()
    hot = max(alive, key=lambda i: (plane.shards[i].open_backlog(), -i))
    t0 = time.perf_counter()
    info = plane.split_shard(hot)
    split_ms = (time.perf_counter() - t0) * 1e3
    p50_after = sample_p50(n_bursts)
    return {
        "clients": clients, "shards": shards,
        "p50_before_us": p50_before, "p50_after_us": p50_after,
        "ratio": p50_after / p50_before if p50_before > 0 else None,
        "split_ms": split_ms, "split": info["split"],
        "target": info["target"], "moved_slots": info["slots"],
        "moved_units": info["reassigned_open"],
    }


def scaling_curve(tiny: bool = False, samples: int | None = None) -> dict:
    rows_spec = TINY_ROWS if tiny else FULL_ROWS
    samples = samples or (300 if tiny else 800)
    rows = [measure_row(c, s, samples) for c, s in rows_spec]
    by_name = {r["name"]: r for r in rows}
    lo = by_name.get(_row_name(*GATE[0]))
    hi = by_name.get(_row_name(*GATE[1]))
    flat_ratio = (hi["p50_us"] / lo["p50_us"]
                  if lo and hi and lo["p50_us"] > 0 else None)
    rebalance = measure_rebalance(samples=samples)
    return {"kind": "scheduler", "tiny": tiny, "samples": samples,
            "rows": rows, "flat_ratio": flat_ratio,
            "gate": [_row_name(*GATE[0]), _row_name(*GATE[1])],
            "rebalance": rebalance}


def capsule_fetch_line() -> str:
    store = ChunkStore()
    server = VBoincServer(store)
    spec = CapsuleSpec("granite-3-2b", "train_4k", RunConfig())
    server.publish(Project("demo", spec))
    key = server.register_user("alice")

    def fetch():
        server.fetch_capsule("demo", set(), key)

    tf = time_fn(fetch, reps=200, warmup=10)
    return csv_line("server.capsule_fetch", tf.us,
                    f"fetches_per_day={86_400.0 / tf.mean_s:.3e}")


def run(tiny: bool = True) -> list[str]:
    """Registry entry point (benchmarks/run.py): CSV lines."""
    curve = scaling_curve(tiny=tiny)
    lines = []
    for r in curve["rows"]:
        lines.append(csv_line(
            f"server.request[{r['name']}]", r["p50_us"],
            f"p99_us={r['p99_us']:.1f};"
            f"tasks_per_day={r['tasks_per_day']:.3e};"
            f"paper=8.8e6;"
            f"ratio={r['tasks_per_day'] / PAPER_TASKS_PER_DAY:.1f}x"))
    fr = curve["flat_ratio"]
    lines.append(csv_line("server.flat_ratio", 0.0,
                          f"p50_{curve['gate'][1]}/p50_{curve['gate'][0]}="
                          f"{fr:.2f}" if fr else "flat_ratio=NA"))
    rb = curve["rebalance"]
    lines.append(csv_line(
        "server.rebalance", rb["p50_after_us"],
        f"p50_before_us={rb['p50_before_us']:.1f};"
        f"ratio={rb['ratio']:.2f};split_ms={rb['split_ms']:.1f};"
        f"moved_units={rb['moved_units']}"))
    lines.append(capsule_fetch_line())
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 10k-100k clients instead of 10k-1M")
    ap.add_argument("--samples", type=int, default=None,
                    help="request_work samples per row")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable curve here")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "run's metrics registry (per-shard dispatch "
                         "counters, flush-batch histograms) here")
    args = ap.parse_args(argv)
    curve = scaling_curve(tiny=args.tiny, samples=args.samples)
    for r in curve["rows"]:
        print(f"  {r['name']:16s} p50 {r['p50_us']:8.1f}us  "
              f"p99 {r['p99_us']:8.1f}us  "
              f"tasks/day {r['tasks_per_day']:.3e}")
    fr = curve["flat_ratio"]
    print(f"  flat_ratio ({curve['gate'][1]} vs {curve['gate'][0]}): "
          f"{fr:.2f}" if fr is not None else "  flat_ratio: NA")
    rb = curve["rebalance"]
    print(f"  rebalance        p50 {rb['p50_before_us']:.1f}us -> "
          f"{rb['p50_after_us']:.1f}us (ratio {rb['ratio']:.2f}), "
          f"split {rb['split_ms']:.1f}ms, "
          f"{rb['moved_units']} units / {rb['moved_slots']} slots moved")
    if args.json:
        Path(args.json).write_text(json.dumps(curve, indent=2))
        print(f"wrote {args.json}")
    if args.telemetry:
        from repro.core import telemetry as tlm
        Path(args.telemetry).write_text(tlm.get_default().prometheus())
        print(f"wrote {args.telemetry}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
