"""§IV-C reproduction: server task-distribution capacity.

Anderson et al. measured ~8.8 M tasks/day for a BOINC server on one cheap
machine.  We measure our scheduler's submit→dispatch→validate cycle cost and
derive tasks/day; the paper predicts V-BOINC server capacity is *lower* and
network-bound (images vs task files) — we report the capsule-transfer bytes
separately so the bandwidth bottleneck is visible.
"""
from __future__ import annotations

import hashlib

from benchmarks.common import csv_line, time_fn
from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.server import Project, VBoincServer
from repro.models.lm import RunConfig

PAPER_TASKS_PER_DAY = 8.8e6


def run(n_tasks: int = 2000) -> list[str]:
    sched = VolunteerScheduler(clock=SimClock())
    for w in range(8):
        sched.join(f"w{w}")
    h = hashlib.sha256(b"result").hexdigest()
    counter = [0]

    def cycle():
        uid = counter[0]
        counter[0] += 1
        sched.submit(uid, {"batch_index": uid})
        wid = f"w{uid % 8}"
        unit = sched.request_work(wid)
        assert unit is not None
        sched.report(wid, unit.unit_id, h)

    t = time_fn(cycle, reps=n_tasks, warmup=50)
    per_day = 86_400.0 / t.mean_s

    # capsule distribution cost (the server's network-bound path)
    store = ChunkStore()
    server = VBoincServer(store)
    spec = CapsuleSpec("granite-3-2b", "train_4k", RunConfig())
    server.publish(Project("demo", spec))
    key = server.register_user("alice")

    def fetch():
        server.fetch_capsule("demo", set(), key)

    tf = time_fn(fetch, reps=200, warmup=10)
    fetch_day = 86_400.0 / tf.mean_s

    return [
        csv_line("server.dispatch_validate", t.us,
                 f"tasks_per_day={per_day:.3e};paper=8.8e6;"
                 f"ratio={per_day / PAPER_TASKS_PER_DAY:.1f}x"),
        csv_line("server.capsule_fetch", tf.us,
                 f"fetches_per_day={fetch_day:.3e}"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
