"""Table II reproduction: snapshot time + state sizes per workload class.

A small real training capsule runs workload variants that write different
state subsets, with periodic differencing snapshots:

  cpu / primes — params FROZEN (pure compute): base disk unchanged -> the
                 paper's minimal 8 KB 'VM snapshot' (here: 0 changed blocks);
  memory       — optimizer-only updates (m/v written, params frozen);
  io / disk    — full training step (params + optimizer written) = heavy
                 'writes to disk';
  sprint       — the pcor case study state (input matrix + result strip).

Columns map 1:1 to the paper: Snapshot Time (s) | Memory Size (state bytes)
| DepDisk Snapshot Size (changed bytes in the mutable DepDisk) | VM Snapshot
Size (changed bytes in the base disk).  The uplink columns close the loop
in the other direction: each round's "dep" update is quantized to int8
(optim/grad_compress) and streamed to a server store as chunk deltas
(core/uplink), so ``uplink_bytes`` is the deduped bytes a volunteer
actually moves up versus ``uplink_dense`` (the whole int8 payload).
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import csv_line
from repro.configs.base import get_arch, reduced
from repro.core.chunkstore import ChunkStore
from repro.core.depdisk import DiskSet
from repro.core.uplink import UplinkEncoder, push_update
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw, grad_compress


def _mutators(tiny: bool = False):
    cfg = reduced(get_arch("granite-3-2b"),
                  n_layers=1 if tiny else 2, d_model=64 if tiny else 128,
                  d_ff=128 if tiny else 256, vocab_size=256 if tiny else 512)
    run = RunConfig(remat="none", block_kv=8, ssm_chunk=8)
    specs = api.state_specs(cfg)
    params = init_tree(specs.params, jax.random.key(0))
    opt = init_tree(specs.opt, jax.random.key(1))
    stream = TokenStream(DataConfig(cfg.vocab_size, 32, 8, seed=3))
    loss_fn = api.make_eval_loss(cfg, run)
    oc = adamw.AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=100)
    grad = jax.jit(jax.value_and_grad(loss_fn))

    def full_step(state, i):
        _, g = grad(state["dep"]["params"], stream.batch(i))
        p, o, _ = adamw.update(oc, g, state["dep"]["opt"],
                               state["dep"]["params"])
        return {"base": state["base"], "dep": {"params": p, "opt": o}}

    def opt_only(state, i):
        _, g = grad(state["base"], stream.batch(i))
        _, o, _ = adamw.update(oc, g, state["dep"]["opt"], state["base"])
        return {"base": state["base"], "dep": {"opt": o,
                                               "params": state["dep"]["params"]}}

    def frozen(state, i):
        loss_fn(state["base"], stream.batch(i))    # compute, no writes
        return state

    def sprint(state, i):
        from repro.kernels.pcor.ops import pcor_strip
        x = state["dep"]["matrix"]
        strip = np.asarray(pcor_strip(x, (i * 64) % 512, 64))
        return {"base": state["base"],
                "dep": {"matrix": x, "result": strip}}

    base_state = {"base": params, "dep": {"params": params, "opt": opt}}
    rng = np.random.default_rng(5)
    sprint_state = {"base": params,
                    "dep": {"matrix": rng.standard_normal((1024, 64))
                            .astype(np.float32),
                            "result": np.zeros((64, 1024), np.float32)}}
    return {
        "cpu": (frozen, base_state),
        "primes": (frozen, base_state),
        "memory": (opt_only, base_state),
        "io": (full_step, base_state),
        "disk": (full_step, base_state),
        "sprint": (sprint, sprint_state),
    }


def _tree_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


def _as_f32(tree):
    return jax.tree.map(lambda x: np.asarray(x, np.float32), tree)


def _run_inline(name, mutate, state0, rounds: int, tmp: Path) -> dict:
    """Inline-writer pass: the round stalls for probe + chunking + hash +
    store file writes + gc (the pre-zero-stall behaviour; also the source
    of the byte columns).  Disk-backed, like a real volunteer host — the
    paper's snapshots persist VDI files, not RAM."""
    store = ChunkStore(tmp / "store", chunk_bytes=1 << 14)   # 16 KiB blocks
    disks = DiskSet(store, root=tmp / "snaps", keep_last=2)
    t0 = time.perf_counter()
    info_base = disks.create_base(state0["base"])
    info_dep0 = disks.attach_dep("task", state0["dep"])
    base_wall = time.perf_counter() - t0
    base_total = info_base.new_bytes + info_dep0.new_bytes
    # uplink: one volunteer streaming its quantized round update into
    # a fresh server-side store (round 0 is the base image)
    uplink_server = ChunkStore(chunk_bytes=1 << 14)
    encoder = UplinkEncoder(chunk_bytes=1 << 14)
    state = state0
    snap_times, dep_bytes, base_bytes = [], [], []
    up_moved, up_dedup, up_dense = [], [], []
    for i in range(rounds):
        state = mutate(state, i)
        jax.block_until_ready(state)   # charge compute to the trainer,
        t0 = time.perf_counter()       # not to the snapshot stall
        dep_info = disks.snapshot_disk("task", state["dep"], step=i)
        base_info = disks.snapshot_disk("base", state["base"], step=i)
        snap_times.append(time.perf_counter() - t0)
        dep_bytes.append(dep_info.new_bytes)
        base_bytes.append(base_info.new_bytes)
        upd = _as_f32(state["dep"])
        comp, _ = grad_compress.compress(upd,
                                         grad_compress.init_error(upd))
        update = encoder.encode(comp)
        moved, dedup = push_update(update, uplink_server,
                                   client_id=name)
        up_moved.append(moved)
        up_dedup.append(dedup)
        up_dense.append(update.dense_bytes)
    return {"state": state, "snap_times": snap_times,
            "dep_bytes": dep_bytes, "base_bytes": base_bytes,
            "base_total": base_total, "base_wall": base_wall,
            "store": store, "up": (up_moved, up_dedup, up_dense)}


def _run_async(mutate, state0, rounds: int, tmp: Path) -> dict:
    """Zero-stall pass over the SAME deterministic state sequence: ONLY the
    device probe + changed-tile transfer on the calling thread; chunking,
    hashing, RLE, store file writes and rebase on the background writer.
    The per-round stall is what the trainer actually waits; writer time is
    measured separately.  Writer depth = rounds so queue backpressure never
    skews the stall figure (it is still accounted and reported)."""
    store = ChunkStore(tmp / "store", chunk_bytes=1 << 14)
    disks = DiskSet(store, root=tmp / "snaps", keep_last=2, async_mode=True,
                    writer_depth=max(2, rounds))
    disks.create_base(state0["base"])
    disks.attach_dep("task", state0["dep"])
    state = state0
    stalls = []
    for i in range(rounds):
        state = mutate(state, i)
        jax.block_until_ready(state)   # same timing convention as inline
        t0 = time.perf_counter()
        disks.snapshot_disk("task", state["dep"], step=i, block=False)
        disks.snapshot_disk("base", state["base"], step=i, block=False)
        stalls.append(time.perf_counter() - t0)
    disks.wait_all()                 # drain writers off the timed path
    disks.gc_all()
    writer_ms = back_ms = 0.0
    for mgr in disks._managers.values():
        ws = mgr.writer_stats
        writer_ms += ws.get("write_ms", 0.0)
        back_ms += ws.get("backpressure_ms", 0.0)
    disks.close_all()
    return {"stalls": stalls, "writer_ms": writer_ms / max(1, rounds),
            "backpressure_ms": back_ms}


def run_rows(rounds: int = 4, tiny: bool = False) -> list[dict]:
    """Per workload: base-image cost (first snapshot) vs differencing cost
    (later snapshots) in bytes and wall time — Table II's shape: CPU-bound
    workloads diff to ~nothing, memory/disk-heavy ones pay for what they
    wrote.  Each round also plays the volunteer uplink: the "dep" update
    is quantized and pushed as chunk deltas; sparse workloads move far
    fewer deduped bytes than the dense int8 wire format.

    Every workload runs TWICE over the same deterministic state sequence —
    inline writer, then async (zero-stall) writer — so ``stall_inline_ms``
    vs ``stall_ms`` is an apples-to-apples per-round trainer-visible
    comparison from one invocation (``stall_ratio`` = inline/async)."""
    rows = []
    for name, (mutate, state0) in _mutators(tiny).items():
        tmp = Path(tempfile.mkdtemp(prefix=f"table2-{name}-"))
        try:
            inline = _run_inline(name, mutate, state0, rounds,
                                 tmp / "inline")
            aio = _run_async(mutate, state0, rounds, tmp / "async")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        state, store = inline["state"], inline["store"]
        dep_bytes, base_bytes = inline["dep_bytes"], inline["base_bytes"]
        up_moved, up_dedup, up_dense = inline["up"]
        mem = _tree_bytes(state)
        diff_total = int(np.mean(dep_bytes)) + int(np.mean(base_bytes))
        # diff rounds only: round 0 is the unavoidable base upload
        u_moved = int(np.mean(up_moved[1:])) if rounds > 1 else up_moved[0]
        u_dedup = int(np.mean(up_dedup[1:])) if rounds > 1 else up_dedup[0]
        stall_inline = float(np.mean(inline["snap_times"])) * 1e3
        stall_async = float(np.mean(aio["stalls"])) * 1e3
        rows.append({
            "name": name,
            "snap_us": float(np.mean(inline["snap_times"])) * 1e6,
            "stall_inline_ms": round(stall_inline, 4),
            "stall_ms": round(stall_async, 4),
            "stall_ratio": round(stall_inline / max(stall_async, 1e-9), 2),
            "writer_ms": round(aio["writer_ms"], 4),
            "backpressure_ms": round(aio["backpressure_ms"], 4),
            "mem_bytes": mem,
            "depdisk_delta": int(np.mean(dep_bytes)),
            "vm_delta": int(np.mean(base_bytes)),
            "base_bytes": inline["base_total"],
            "base_wall_us": round(inline["base_wall"] * 1e6),
            "diff_bytes": diff_total,
            "diff_ratio": round(diff_total / max(1, inline["base_total"]),
                                4),
            "delta_objects": store.stats["delta_chunks"],
            "rebased": store.stats["rebased"],
            "uplink_bytes": u_moved,
            "uplink_dedup": u_dedup,
            "uplink_dense": int(np.mean(up_dense)),
            "uplink_base": up_moved[0],
        })
    return rows


def _format(rows: list[dict]) -> list[str]:
    lines = []
    for r in rows:
        derived = ";".join(f"{k}={r[k]}" for k in (
            "stall_inline_ms", "stall_ms", "stall_ratio", "writer_ms",
            "backpressure_ms",
            "mem_bytes", "depdisk_delta", "vm_delta", "base_bytes",
            "base_wall_us", "diff_bytes", "diff_ratio", "delta_objects",
            "rebased", "uplink_bytes", "uplink_dedup", "uplink_dense",
            "uplink_base"))
        lines.append(csv_line(f"table2.{r['name']}", r["snap_us"], derived))
    return lines


def run(rounds: int = 4) -> list[str]:
    return _format(run_rows(rounds))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--tiny", action="store_true",
                    help="smallest config (CI benchmark smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "run's metrics registry (chunk-store put/dedup "
                         "counters, writer stall accumulators) here")
    args = ap.parse_args(argv)
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    rows = run_rows(args.rounds, tiny=args.tiny)
    print("\n".join(_format(rows)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "table2_snapshots", "rounds": args.rounds,
                       "tiny": args.tiny, "rows": rows}, f, indent=2)
    if args.telemetry:
        from repro.core import telemetry as tlm
        Path(args.telemetry).write_text(tlm.get_default().prometheus())


if __name__ == "__main__":
    main()
