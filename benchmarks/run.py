"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section markers).  Scaled for
the CPU container; see EXPERIMENTS.md for the recorded runs + analysis.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (edge_egress, fig3_overhead, fig4_sprint_pcor,
                            replica_failover, roofline, server_throughput,
                            table2_snapshots, telemetry_overhead)

    sections = [
        ("fig3 (benchmark overhead, 4 platforms)", fig3_overhead.run),
        ("fig4 (SPRINT pcor load/exec)", fig4_sprint_pcor.run),
        ("table2 (snapshot time/sizes)", table2_snapshots.run),
        ("server (§IV-C throughput)", server_throughput.run),
        ("replica (fan-out + failover)", replica_failover.run),
        ("edge (discovery + cache egress)", edge_egress.run),
        ("roofline (dry-run derived)", roofline.run),
        ("telemetry (tracing overhead)", telemetry_overhead.run),
    ]
    print("name,us_per_call,derived")
    ok = True
    for title, fn in sections:
        t0 = time.time()
        try:
            for line in fn():
                print(line)
        except Exception as e:  # keep the harness honest: report, fail exit
            ok = False
            print(f"{title.split()[0]}.ERROR,0,{type(e).__name__}: {e}")
        print(f"# section '{title}' took {time.time() - t0:.1f}s",
              file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
