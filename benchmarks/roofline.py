"""Roofline table generator: reads the dry-run JSONs (§Dry-run) and emits
the per-(arch × shape × mesh) three-term table for EXPERIMENTS.md §Roofline.

Also measures the snapshot probe kernel itself (``roofline.snapshot.*``):
launches-per-snapshot for the size-bucketed whole-tree diff versus the
per-leaf path — the bucketed count must be O(size buckets), not O(leaves)
— and the probe's streaming bandwidth (it reads old + new once, so it
should sit near memory bandwidth, the roofline's memory term).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import csv_line

DRYRUN_DIR = Path("experiments/dryrun")


def snapshot_kernel_stats(leaves: int = 64, repeats: int = 5,
                          mode: str = "ref") -> dict:
    """Probe a synthetic optimizer-like tree (many small leaves, a few
    size classes) through the DeviceMirror, bucketed vs per-leaf.

    -> {leaves, buckets, launches_bucketed, launches_per_leaf,
        probe_gbps, d2h_frac} — launch counts for ONE whole-tree
    snapshot, read from KERNEL_STATS."""
    from repro.kernels.delta_encode.ops import (DeviceMirror, probe_leaves,
                                                reset_kernel_stats,
                                                KERNEL_STATS)
    rng = np.random.default_rng(7)
    sizes = [2048, 8192, 33000, 131072]          # ~4 pow2 tile classes
    news = {f"leaf{i:03d}": rng.standard_normal(sizes[i % len(sizes)])
            .astype(np.float32) for i in range(leaves)}

    def mutated(tree, r):
        out = {}
        for j, (k, v) in enumerate(tree.items()):
            if j % 2 == r % 2:                   # touch half the leaves
                w = v.copy()
                w[::97] += 1.0
                out[k] = w
            else:
                out[k] = v.copy()                # new object, same bytes
        return out

    results = {}
    for label, bucketed in (("bucketed", True), ("per_leaf", False)):
        mirror = DeviceMirror()
        probe_leaves(news, mode=mode, mirror=mirror, bucketed=bucketed)
        state, dt = news, 0.0
        reset_kernel_stats()
        for r in range(repeats):
            state = mutated(state, r)
            t0 = time.perf_counter()
            probe_leaves(state, mode=mode, mirror=mirror, bucketed=bucketed)
            dt += time.perf_counter() - t0
        stats = dict(KERNEL_STATS)
        results[label] = (stats, dt)
        reset_kernel_stats()
    b_stats, b_dt = results["bucketed"]
    l_stats, _ = results["per_leaf"]
    return {
        "leaves": leaves,
        "launches_bucketed": b_stats["launches"] // repeats,
        "launches_per_leaf": l_stats["launches"] // repeats,
        "probe_gbps": b_stats["probe_bytes"] / max(b_dt, 1e-9) / 1e9,
        "d2h_frac": b_stats["d2h_bytes"] / max(1, b_stats["probe_bytes"]),
    }


def load_records(dryrun_dir: Path = DRYRUN_DIR) -> list[dict]:
    recs = []
    for p in sorted(dryrun_dir.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r.get("status") != "ok":
            continue
        f = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {f['compute_s']:.3e} | {f['memory_s']:.3e} "
            f"| {f['collective_s']:.3e} | {f['dominant']} "
            f"| {f['useful_flops_ratio']:.2f} "
            f"| {f['roofline_fraction']:.2f} |")
    return "\n".join(rows)


def snapshot_kernel_rows() -> list[str]:
    s = snapshot_kernel_stats()
    return [
        csv_line("roofline.snapshot.launches_per_snapshot",
                 float(s["launches_bucketed"]),
                 f"leaves={s['leaves']};bucketed={s['launches_bucketed']};"
                 f"per_leaf={s['launches_per_leaf']}"),
        csv_line("roofline.snapshot.probe_gbps", s["probe_gbps"],
                 f"d2h_frac={s['d2h_frac']:.4f}"),
    ]


def run() -> list[str]:
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    lines = snapshot_kernel_rows()
    lines += [csv_line("roofline.cells_ok", 0.0, f"count={len(ok)}"),
              csv_line("roofline.cells_skipped", 0.0,
                       f"count={len(skipped)} (documented)"),
              csv_line("roofline.cells_error", 0.0, f"count={len(err)}")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        best = max(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["bound_s"], 1e-30))
        lines += [
            csv_line("roofline.worst", 0.0,
                     f"{worst['arch']}/{worst['shape']}/{worst['mesh']}="
                     f"{worst['roofline']['roofline_fraction']:.3f}"),
            csv_line("roofline.best", 0.0,
                     f"{best['arch']}/{best['shape']}/{best['mesh']}="
                     f"{best['roofline']['roofline_fraction']:.3f}"),
            csv_line("roofline.most_collective_bound", 0.0,
                     f"{coll['arch']}/{coll['shape']}/{coll['mesh']}"),
        ]
    return lines


if __name__ == "__main__":
    print(table(load_records()))
    print("\n".join(run()))
