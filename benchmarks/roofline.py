"""Roofline table generator: reads the dry-run JSONs (§Dry-run) and emits
the per-(arch × shape × mesh) three-term table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_line

DRYRUN_DIR = Path("experiments/dryrun")


def load_records(dryrun_dir: Path = DRYRUN_DIR) -> list[dict]:
    recs = []
    for p in sorted(dryrun_dir.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r.get("status") != "ok":
            continue
        f = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {f['compute_s']:.3e} | {f['memory_s']:.3e} "
            f"| {f['collective_s']:.3e} | {f['dominant']} "
            f"| {f['useful_flops_ratio']:.2f} "
            f"| {f['roofline_fraction']:.2f} |")
    return "\n".join(rows)


def run() -> list[str]:
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    lines = [csv_line("roofline.cells_ok", 0.0, f"count={len(ok)}"),
             csv_line("roofline.cells_skipped", 0.0,
                      f"count={len(skipped)} (documented)"),
             csv_line("roofline.cells_error", 0.0, f"count={len(err)}")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        best = max(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
                   / max(r["roofline"]["bound_s"], 1e-30))
        lines += [
            csv_line("roofline.worst", 0.0,
                     f"{worst['arch']}/{worst['shape']}/{worst['mesh']}="
                     f"{worst['roofline']['roofline_fraction']:.3f}"),
            csv_line("roofline.best", 0.0,
                     f"{best['arch']}/{best['shape']}/{best['mesh']}="
                     f"{best['roofline']['roofline_fraction']:.3f}"),
            csv_line("roofline.most_collective_bound", 0.0,
                     f"{coll['arch']}/{coll['shape']}/{coll['mesh']}"),
        ]
    return lines


if __name__ == "__main__":
    print(table(load_records()))
    print("\n".join(run()))
