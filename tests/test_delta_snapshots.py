"""Delta-snapshot pipeline tests: device-resident differencing through
ChunkStore delta objects → v2 manifests → trainer restore → server sync.

Covers the acceptance criteria: bit-exact restore across ≥3-deep delta
chains (fp32 + bf16 with NaN payloads), v1-manifest backward compat,
chain-cap rebasing, ~0 new bytes for an unchanged state, and the <5%
changed blocks → <10% stored bytes bound.
"""
import json

import numpy as np
import pytest

from repro.core.chunkstore import ChunkStore, is_delta_ref
from repro.core.elastic import VolunteerTrainer
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.server import Project, VBoincServer
from repro.core.snapshots import Manifest, SnapshotManager, TensorEntry
from repro.data.pipeline import Cursor


def _bitcast(u32):
    return np.asarray(u32, np.uint32).view(np.float32)


def _nanful(rng, n, dtype):
    """Random payload with exotic bit patterns (NaN payloads, ±Inf, -0)."""
    x = rng.standard_normal(n).astype(np.float32)
    x[::97] = _bitcast(0x7FC00001)       # quiet NaN with payload
    x[1::131] = _bitcast(0xFF800000)     # -Inf
    x[2::151] = _bitcast(0x80000000)     # -0.0
    if dtype == "bfloat16":
        import jax.numpy as jnp
        return np.asarray(jnp.asarray(x, jnp.bfloat16))
    return x


def _bits(a):
    return np.asarray(a).reshape(-1).view(np.uint8)


# ---------------------------------------------------------------------------
# deep delta chains, fp32 + bf16, NaN payloads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_deep_delta_chain_bit_exact(dtype):
    store = ChunkStore(chunk_bytes=1 << 12, max_chain=16)
    mgr = SnapshotManager(store, keep_last=10)
    rng = np.random.default_rng(0)
    x = _nanful(rng, 20_000, dtype)
    states = []
    for i in range(5):                    # base + 4 diffs -> chain depth >= 3
        x = x.copy()
        x[i * 11:i * 11 + 7] = _nanful(rng, 7, dtype)
        mgr.snapshot({"x": x, "step": np.int32(i)}, step=i)
        states.append(x.copy())
    # the chain really is delta objects, >= 3 deep
    last_refs = mgr.manifests[mgr.order[-1]].tensors["['x']"].refs
    depths = [store.ref_depth(r) for r in last_refs if is_delta_ref(r)]
    assert depths and max(depths) >= 3
    # every snapshot in the chain restores bit-exactly
    for sid, want in zip(mgr.order, states):
        got, _ = mgr.restore(sid, target_tree={"x": np.zeros_like(want),
                                               "step": np.int32(0)})
        assert np.array_equal(_bits(got["x"]), _bits(want))


def test_delta_snapshot_via_pallas_interpret():
    """The Pallas kernel path (interpret mode) is wired end-to-end."""
    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store, keep_last=5, delta_mode="interpret")
    x = np.arange(40_000, dtype=np.float32)
    mgr.snapshot({"x": x}, step=0)
    y = x.copy()
    y[123] = np.float32(np.nan)
    info = mgr.snapshot({"x": y}, step=1)
    assert 0 < info.new_bytes < x.nbytes // 10
    got, _ = mgr.restore(target_tree={"x": np.zeros_like(x)})
    assert np.array_equal(_bits(got["x"]), _bits(y))


# ---------------------------------------------------------------------------
# unchanged state stores ~0 new bytes; <5% blocks -> <10% of base bytes
# ---------------------------------------------------------------------------
def test_unchanged_state_stores_zero_bytes():
    mgr = SnapshotManager(ChunkStore(chunk_bytes=1 << 12))
    state = {"a": np.random.default_rng(1).standard_normal(30_000)
             .astype(np.float32), "b": np.int32(7)}
    mgr.snapshot(state, step=0)
    info = mgr.snapshot(state, step=1)
    assert info.kind == "diff"
    assert info.new_bytes == 0
    assert info.changed_chunks == 0 and info.reused_chunks > 0


def test_sparse_change_stores_under_10pct_of_base():
    store = ChunkStore(chunk_bytes=1 << 12)          # 256 blocks of 4 KiB
    mgr = SnapshotManager(store, keep_last=5)
    rng = np.random.default_rng(2)
    x = rng.standard_normal(262_144).astype(np.float32)   # 1 MiB
    base = mgr.snapshot({"x": x}, step=0)
    y = x.copy()
    y[0] += 1.0                      # touches 2 of 256 blocks (<5%)
    y[200_000] += 1.0
    diff = mgr.snapshot({"x": y}, step=1)
    assert diff.new_bytes < base.new_bytes * 0.10
    assert diff.changed_chunks <= 4
    got, _ = mgr.restore(target_tree={"x": np.zeros_like(x)})
    assert np.array_equal(_bits(got["x"]), _bits(y))


# ---------------------------------------------------------------------------
# chain cap -> rebase to a fresh base
# ---------------------------------------------------------------------------
def test_chain_cap_rebases_and_restores():
    store = ChunkStore(chunk_bytes=1 << 12, max_chain=2)
    mgr = SnapshotManager(store, keep_last=20)
    x = np.random.default_rng(3).standard_normal(40_000).astype(np.float32)
    for i in range(8):
        x = x.copy()
        x[5] = float(i)
        mgr.snapshot({"x": x}, step=i)
    assert store.stats["rebased"] > 0
    for ent in (mgr.manifests[s].tensors["['x']"] for s in mgr.order):
        assert all(store.ref_depth(r) <= 2 for r in ent.refs)
    got, _ = mgr.restore(target_tree={"x": np.zeros_like(x)})
    assert np.array_equal(_bits(got["x"]), _bits(x))


# ---------------------------------------------------------------------------
# v1 manifest backward compat
# ---------------------------------------------------------------------------
def test_v1_manifest_restore():
    store = ChunkStore(chunk_bytes=1 << 12)
    arr = np.arange(9_999, dtype=np.float32)
    hashes = store.put_buffer(memoryview(arr).cast("B"))
    v1 = json.dumps({                     # exactly what the v1 code wrote
        "snapshot_id": "snap-000001-deadbeef", "parent": None,
        "step": 3, "created": 0.0, "kind": "base",
        "aux": {"cursor": {"next_index": 4}},
        "tensors": {"['x']": {"shape": [9999], "dtype": "float32",
                              "hashes": hashes}},
    })
    man = Manifest.from_json(v1)
    assert man.version == 1
    assert man.tensors["['x']"].refs == hashes     # alias mapping
    mgr = SnapshotManager(store)
    mgr.manifests[man.snapshot_id] = man
    mgr.order.append(man.snapshot_id)
    got, aux = mgr.restore(target_tree={"x": np.zeros_like(arr)})
    assert np.array_equal(got["x"], arr)
    assert aux["cursor"]["next_index"] == 4


def test_v1_entry_hashes_alias_roundtrip():
    ent = TensorEntry((4,), "float32", ["abc"])
    assert ent.hashes == ent.refs == ["abc"]
    assert TensorEntry.from_json(ent.to_json()).refs == ["abc"]


# ---------------------------------------------------------------------------
# trainer-level restore through a delta chain + download accounting
# ---------------------------------------------------------------------------
def test_trainer_restore_latest_through_delta_chain():
    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store, keep_last=10)
    rng = np.random.default_rng(4)
    x = rng.standard_normal(30_000).astype(np.float32)
    early_refs: set = set()
    for i in range(4):
        x = x.copy()
        x[i] = np.float32(np.nan)
        mgr.snapshot({"params": x}, step=i,
                     aux={"cursor": Cursor(next_index=i + 1).to_state(),
                          "round": i})
        if i == 0:
            early_refs = set(mgr.manifests[mgr.order[-1]].all_refs())
    tr = VolunteerTrainer(grad_fn=None, apply_fn=None, state=None,
                          stream=None, micro_batches=1, snapshots=mgr)
    next_step = tr.restore_latest({"params": np.zeros_like(x)},
                                  client_hashes=early_refs)
    assert next_step == 4
    assert np.array_equal(_bits(tr.state["params"]), _bits(x))
    assert tr.cursor.next_index == 4
    # re-attach accounting: the volunteer holding the base downloads only
    # the delta objects written since it detached
    plan = tr.last_restore_plan
    assert plan is not None and plan["missing"] > 0
    assert 0 < plan["bytes_moved"] < x.nbytes // 10
    assert plan["bytes_dedup"] > 0


# ---------------------------------------------------------------------------
# resume scan: highest (step, created) wins even when v1 + v2 manifests
# coexist in one directory and filename order lies (PR 1 fix, now shared
# by launch/train.py --resume through SnapshotManager.load_existing)
# ---------------------------------------------------------------------------
def test_load_existing_picks_highest_step_across_v1_v2(tmp_path):
    store = ChunkStore(tmp_path / "store", chunk_bytes=1 << 12)
    root = tmp_path / "snaps"
    (root / "manifests").mkdir(parents=True)
    old = np.arange(2000, dtype=np.float32)
    new = old + 1.0
    v2_refs = store.put_buffer(memoryview(old).cast("B"))
    v1_refs = store.put_buffer(memoryview(new).cast("B"))
    # v2 manifest at step 2 whose snapshot id sorts LAST by filename
    v2 = json.dumps({
        "version": 2, "snapshot_id": "snap-000009-ffffffff", "parent": None,
        "step": 2, "created": 50.0, "kind": "base",
        "aux": {"cursor": {"next_index": 3}, "round": 2},
        "tensors": {"['x']": {"shape": [2000], "dtype": "float32",
                              "refs": v2_refs}}})
    # v1 manifest (pre-delta process) at step 5: older id, NEWER step
    v1 = json.dumps({
        "snapshot_id": "snap-000001-aaaaaaaa", "parent": None,
        "step": 5, "created": 99.0,
        "aux": {"cursor": {"next_index": 6}, "round": 5},
        "tensors": {"['x']": {"shape": [2000], "dtype": "float32",
                              "hashes": v1_refs}}})
    (root / "manifests" / "snap-000009-ffffffff.json").write_text(v2)
    (root / "manifests" / "snap-000001-aaaaaaaa.json").write_text(v1)

    mgr = SnapshotManager(store, root=root, keep_last=10)
    assert mgr.load_existing() == 2
    assert mgr.latest() == "snap-000001-aaaaaaaa"   # step order, not name
    assert mgr.load_existing() == 0                 # idempotent re-scan

    tr = VolunteerTrainer(grad_fn=None, apply_fn=None, state=None,
                          stream=None, micro_batches=1, snapshots=mgr)
    next_step = tr.restore_latest({"x": np.zeros_like(new)})
    assert next_step == 6
    assert np.array_equal(_bits(tr.state["x"]), _bits(new))
    assert tr.cursor.next_index == 6
    # a snapshot taken after adoption must not collide with adopted ids
    info = mgr.snapshot({"x": new + 1.0}, step=6)
    assert info.snapshot_id not in ("snap-000001-aaaaaaaa",
                                    "snap-000009-ffffffff")
    assert mgr.latest() == info.snapshot_id


# ---------------------------------------------------------------------------
# server-side block sync for a re-attaching volunteer
# ---------------------------------------------------------------------------
def test_server_reattach_moves_only_deltas():
    from repro.core.capsule import CapsuleSpec
    from repro.models.lm import RunConfig

    store = ChunkStore(chunk_bytes=1 << 12)
    # the store is SHARED with the server's capsule chunks, so the manager
    # must not sweep it on its own (the DiskSet rule)
    mgr = SnapshotManager(store, keep_last=10, auto_gc=False)
    x = np.random.default_rng(5).standard_normal(30_000).astype(np.float32)
    mgr.snapshot({"params": x}, step=0)

    server = VBoincServer(store)
    spec = CapsuleSpec("qwen2-1.5b", "train_4k", RunConfig())
    proj = Project("lm", spec, scheduler=VolunteerScheduler(clock=SimClock()))
    proj.snapshots = mgr
    server.publish(proj)
    key = server.register_user("vol")
    # account keys are restart-stable (sha256, not salted hash())
    assert key == server.register_user("vol")

    _, missing1, moved1 = server.fetch_capsule("lm", set(), key)
    assert moved1 > x.nbytes // 2          # first attach: ~everything moves
    client = set(missing1)
    y = x.copy()
    y[7] = 42.0
    mgr.snapshot({"params": y}, step=1)
    _, missing2, moved2 = server.fetch_capsule("lm", client, key)
    assert missing2 and all(r not in client for r in missing2)
    assert 0 < moved2 < moved1 // 10       # only the new delta objects move
    # the moved refs resolve to the new state
    client |= set(missing2)
    _, missing3, moved3 = server.fetch_capsule("lm", client, key)
    assert moved3 == 0 and not missing3


# ---------------------------------------------------------------------------
# failure hygiene: a failed store write must not poison later snapshots
# ---------------------------------------------------------------------------
def test_failed_write_does_not_corrupt_next_snapshot():
    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store)
    x = np.random.default_rng(6).standard_normal(20_000).astype(np.float32)
    mgr.snapshot({"x": x}, step=0)
    y = x.copy()
    y[3] = 9.0
    real_put_delta = store.put_delta
    store.put_delta = lambda *a, **k: (_ for _ in ()).throw(IOError("disk"))
    with pytest.raises(IOError):
        mgr.snapshot({"x": y}, step=1)   # planning advanced the mirror...
    store.put_delta = real_put_delta
    z = y.copy()
    z[4] = 10.0
    mgr.snapshot({"x": z}, step=2)       # ...but recovery re-bases cleanly
    got, _ = mgr.restore(target_tree={"x": np.zeros_like(x)})
    assert np.array_equal(_bits(got["x"]), _bits(z))


def test_failed_planning_does_not_corrupt_next_snapshot(monkeypatch):
    """A plan-phase failure (e.g. device OOM mid-diff) advances some
    tensors' mirrors but not their refs; the next snapshot must re-base
    rather than record stale parent refs."""
    import repro.core.snapshots as snapmod

    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store)
    rng = np.random.default_rng(8)
    a = rng.standard_normal(20_000).astype(np.float32)
    b = rng.standard_normal(20_000).astype(np.float32)
    mgr.snapshot({"a": a, "b": b}, step=0)

    real = snapmod.chunk_records
    calls = {"n": 0}

    def boom(*a_, **kw):
        calls["n"] += 1
        if calls["n"] == 2:              # tensor "a" planned, "b" explodes
            raise RuntimeError("device fell over")
        return real(*a_, **kw)

    monkeypatch.setattr(snapmod, "chunk_records", boom)
    a2, b2 = a.copy(), b.copy()
    a2[0], b2[0] = 1.5, 2.5
    with pytest.raises(RuntimeError):
        mgr.snapshot({"a": a2, "b": b2}, step=1)
    monkeypatch.setattr(snapmod, "chunk_records", real)
    a3, b3 = a2.copy(), b2.copy()
    a3[1], b3[1] = 3.5, 4.5
    mgr.snapshot({"a": a3, "b": b3}, step=2)
    got, _ = mgr.restore(target_tree={"a": np.zeros_like(a),
                                      "b": np.zeros_like(b)})
    assert np.array_equal(_bits(got["a"]), _bits(a3))
    assert np.array_equal(_bits(got["b"]), _bits(b3))


# ---------------------------------------------------------------------------
# RLE: dense payloads take the O(1) literal bail-out, and it round-trips
# ---------------------------------------------------------------------------
def test_rle_dense_payload_bails_to_literal():
    from repro.core.chunkstore import rle_zero_encode, rle_zero_decode

    rng = np.random.default_rng(7)
    # every 4th byte nonzero: the classic fp32 low-byte-churn XOR shape
    dense = np.zeros(1 << 16, np.uint8)
    dense[::4] = rng.integers(1, 256, dense[::4].size, dtype=np.uint8)
    enc = rle_zero_encode(dense.tobytes())
    assert len(enc) == dense.size + 5          # single literal token
    assert rle_zero_decode(enc, dense.size) == dense.tobytes()
    sparse = np.zeros(1 << 16, np.uint8)
    sparse[100:140] = 7
    enc = rle_zero_encode(sparse.tobytes())
    assert len(enc) < 100                      # RLE engaged
    assert rle_zero_decode(enc, sparse.size) == sparse.tobytes()


# ---------------------------------------------------------------------------
# scheduler pending-index semantics survive the O(1) refactor
# ---------------------------------------------------------------------------
def test_scheduler_resubmit_completed_unit_not_duplicated():
    clock = SimClock()
    s = VolunteerScheduler(clock=clock)
    s.join("w")
    s.submit(0, {})
    s.request_work("w")
    s.report("w", 0, "H")
    s.submit(0, {})                  # re-issue the same unit id
    assert len(s.pending()) == 1
    assert s.request_work("w").unit_id == 0
    s.report("w", 0, "H")
    assert s.done()



def test_scheduler_dispatch_skips_completed_backlog():
    clock = SimClock()
    s = VolunteerScheduler(clock=clock)
    s.join("w")
    for uid in range(500):
        s.submit(uid, {})
        unit = s.request_work("w")
        assert unit is not None and unit.unit_id == uid
        s.report("w", uid, "H")
        assert s.done()
    # the pending index is empty — a new unit dispatches immediately
    s.submit(500, {})
    assert len(s.pending()) == 1
    assert s.request_work("w").unit_id == 500
