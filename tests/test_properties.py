"""Property-based tests (hypothesis) on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.snapshots import SnapshotManager
from repro.data.pipeline import DataConfig, TokenStream
from repro.kernels.delta_encode.ops import diff_blocks, patch_blocks

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Invariant: delta-encode roundtrip is bit-exact for arbitrary mutations
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(size=st.integers(1, 40_000), nmut=st.integers(0, 64),
       seed=st.integers(0, 2 ** 31))
def test_delta_roundtrip_property(size, nmut, seed):
    rng = np.random.default_rng(seed)
    old = rng.standard_normal(size).astype(np.float32)
    new = old.copy()
    if nmut and size:
        idx = rng.integers(0, size, min(nmut, size))
        new[idx] = rng.standard_normal(idx.size).astype(np.float32)
    tiles, bitmap, _ = diff_blocks(old, new, mode="ref")
    rec = patch_blocks(old, tiles, bitmap, mode="ref")
    assert np.array_equal(rec.view(np.uint8), new.view(np.uint8))
    # changed-block count is minimal: identical arrays -> no blocks
    if np.array_equal(old.view(np.uint8), new.view(np.uint8)):
        assert bitmap.sum() == 0


# ---------------------------------------------------------------------------
# Invariant: snapshot chain restores every retained snapshot exactly,
# regardless of mutation pattern, chunk size and keep_last
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(chunk_log2=st.integers(8, 14), keep=st.integers(1, 4),
       steps=st.integers(1, 6), seed=st.integers(0, 2 ** 31))
def test_snapshot_chain_property(chunk_log2, keep, steps, seed):
    rng = np.random.default_rng(seed)
    mgr = SnapshotManager(ChunkStore(chunk_bytes=2 ** chunk_log2),
                          keep_last=keep)
    states, sids = [], []
    w = rng.standard_normal(3000).astype(np.float32)
    for i in range(steps):
        mut = rng.integers(0, w.size, 50)
        w = w.copy()
        w[mut] += 1.0
        state = {"w": w, "step": np.int32(i)}
        info = mgr.snapshot(state, step=i)
        states.append(state)
        sids.append(info.snapshot_id)
    # every retained snapshot restores exactly
    for sid, state in list(zip(sids, states))[-keep:]:
        got, _ = mgr.restore(sid, target_tree=state)
        np.testing.assert_array_equal(got["w"], state["w"])
        assert got["step"] == state["step"]


# ---------------------------------------------------------------------------
# Invariant: the scheduler completes ALL units under arbitrary failure
# interleavings (workers dying, leases expiring, corrupt minorities)
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(n_units=st.integers(1, 8), n_workers=st.integers(2, 6),
       seed=st.integers(0, 2 ** 31))
def test_scheduler_always_completes(n_units, n_workers, seed):
    rng = np.random.default_rng(seed)
    clock = SimClock()
    s = VolunteerScheduler(replication=2, quorum=2, deadline_s=10.0,
                           max_extra_results=32, clock=clock)
    for u in range(n_units):
        s.submit(u, {})
    workers = [f"w{i}" for i in range(n_workers)]
    for w in workers:
        s.join(w)
    alive = set(workers)
    for _ in range(10_000):
        if s.done():
            break
        progressed = False
        for w in list(alive):
            unit = s.request_work(w)
            if unit is None:
                continue
            progressed = True
            r = rng.random()
            if r < 0.10 and len(alive) > 2:     # dies holding the lease
                s.leave(w)
                alive.discard(w)
            elif r < 0.25:                       # corrupt result
                s.report(w, unit.unit_id, f"bad-{rng.integers(1e9)}")
            else:                                # honest deterministic result
                s.report(w, unit.unit_id, f"good-{unit.unit_id}")
        if not progressed:
            clock.advance(100.0)
            # volunteers keep arriving — a stuck quorum (every current
            # worker already reported) needs fresh hosts
            nw = f"spawn{rng.integers(1e9)}"
            s.join(nw)
            alive.add(nw)
    assert s.done()
    # canonical results are always the honest ones
    for uid, h in s.canonical_results().items():
        assert h == f"good-{uid}"


# ---------------------------------------------------------------------------
# Invariant: data pipeline is deterministic random-access (work-unit replay)
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 31), index=st.integers(0, 10_000))
def test_pipeline_random_access_determinism(seed, index):
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2, seed=seed)
    a = TokenStream(cfg).batch(index)
    b = TokenStream(cfg).batch(index)           # fresh instance, same result
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 128
    # labels are inputs shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Invariant: chunk store never loses a live chunk across arbitrary gc calls
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(n=st.integers(1, 30), seed=st.integers(0, 2 ** 31))
def test_chunkstore_gc_property(n, seed):
    rng = np.random.default_rng(seed)
    store = ChunkStore(chunk_bytes=256)
    hashes = [store.put(rng.bytes(rng.integers(1, 512))) for _ in range(n)]
    live = set(rng.choice(hashes, size=rng.integers(0, n + 1),
                          replace=False).tolist())
    store.gc(live)
    for h in hashes:
        assert store.has(h) == (h in live) or h in live
