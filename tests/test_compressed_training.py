"""Volunteer training with int8+EF gradient compression still learns, and
its wire savings are what grad_compress promises."""
import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw, grad_compress

RUN = RunConfig(remat="none", block_kv=8, ssm_chunk=8)
OC = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=500)


def _build(compress: bool):
    cfg = reduced(get_arch("granite-3-2b"))
    specs = api.state_specs(cfg)
    loss_fn = api.make_eval_loss(cfg, RUN)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def apply_fn(state, grads):
        p, o, _ = adamw.update(OC, grads, state.opt, state.params)
        return api.TrainState(p, o)

    state = api.TrainState(init_tree(specs.params, jax.random.key(0)),
                           init_tree(specs.opt, jax.random.key(0)))
    tr = VolunteerTrainer(
        grad_fn=grad_fn, apply_fn=apply_fn, state=state,
        stream=TokenStream(DataConfig(cfg.vocab_size, 32, 4, seed=0)),
        micro_batches=2, compress_grads=compress)
    tr.add_worker(SimWorker("w0"))
    tr.add_worker(SimWorker("w1"))
    return tr


def test_compressed_training_learns():
    ref = _build(False).run(10)
    comp_tr = _build(True)
    comp = comp_tr.run(10)
    # compression still converges, tracking the exact run closely
    assert comp[-1].loss < comp[0].loss - 0.1
    assert abs(comp[-1].loss - ref[-1].loss) < 0.15
    # error-feedback state is alive and bounded
    err = comp_tr._compress_err
    enorm = max(float(np.abs(np.asarray(e)).max())
                for e in jax.tree.leaves(err))
    assert np.isfinite(enorm)


def test_wire_savings_on_real_grads():
    tr = _build(False)
    _, grads = tr.grad_fn(tr.state.params, tr.stream.batch(0))
    raw, comp = grad_compress.wire_bytes(grads)
    assert raw / comp > 3.5
