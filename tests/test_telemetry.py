"""Telemetry plane tests: registry, tracer, flight recorder, trace_reduce.

The headline acceptance test runs seeded volunteer training under churn
(worker deaths, a mid-round scheduler-shard kill, a primary-store wipe +
promote) and proves, from the flight-recorder stream alone, that

* every completed unit has a closed ``submit -> dispatch -> report ->
  quorum -> fold`` chain;
* every reissue is attributable to a recorded fault event (100%);
* two runs with the same seed produce byte-identical event streams.
"""
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import telemetry as tlm
from repro.core.chunkstore import ChunkStore
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.replica import ReplicaSet
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.shardplane import ShardedScheduler
from repro.core.sim import ChurnSim
from repro.core.snapshots import SnapshotManager
from repro.models import api

REPO = Path(__file__).resolve().parents[1]

N = 4096
CHUNK = 1 << 12


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_registry_counters_and_readonly_view():
    tel = tlm.Telemetry()
    scope = tel.scope("demo")
    m = scope.counters("a", "b")
    view = scope.view()
    m.a.inc()
    m.a.inc(4)
    m.b.inc(-2)                      # clawback path: negatives allowed
    assert view["a"] == 5 and view["b"] == -2
    assert dict(view) == {"a": 5, "b": -2}
    assert view.get("missing", 7) == 7
    assert "a" in view and len(view) == 2
    assert view == {"a": 5, "b": -2}             # Mapping equality
    with pytest.raises(TypeError):
        view["a"] = 9
    with pytest.raises(TypeError):
        view["a"] += 1
    with pytest.raises(TypeError):
        del view["a"]
    # the view is live: later registrations and increments show through
    scope.counter("c").inc(3)
    assert view["c"] == 3
    g = scope.gauge("depth")
    g.set(11)
    assert view["depth"] == 11
    # re-registration returns the same object (idempotent)
    assert scope.counter("a") is m.a


def test_histogram_buckets_and_prometheus():
    tel = tlm.Telemetry()
    scope = tel.scope("sched")
    scope.counter("done").inc(2)
    h = scope.histogram("lat", (0.001, 0.01, 0.1))
    for v in (0.0005, 0.005, 0.005, 0.05, 5.0):
        h.observe(v)
    assert h.count == 5 and h.counts == [1, 2, 1, 1]
    text = tel.prometheus()
    assert '# TYPE repro_sched_done counter' in text
    assert 'repro_sched_done{scope="sched",instance="0"} 2' in text
    assert '# TYPE repro_sched_lat histogram' in text
    # cumulative le buckets + the +Inf total
    assert 'le="0.001"} 1' in text
    assert 'le="0.01"} 3' in text
    assert 'le="0.1"} 4' in text
    assert 'le="+Inf"} 5' in text
    assert 'repro_sched_lat_count{scope="sched",instance="0"} 5' in text
    # second scope of the same name gets a distinct instance label
    tel.scope("sched").counter("done").inc()
    assert 'instance="1"' in tel.prometheus()


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_bounded_ring_and_deterministic_dump(tmp_path):
    clock = SimClock()
    tel = tlm.Telemetry(tracing=True, clock=clock, capacity=4)
    for i in range(10):
        clock.advance(1.0)
        seq = tel.event("tick", unit=i)
        assert seq == i + 1
    assert len(tel.events) == 4                    # ring bound
    assert [e["seq"] for e in tel.events] == [7, 8, 9, 10]
    p = tmp_path / "dump.jsonl"
    assert tel.dump_jsonl(p) == 4
    assert tlm.load_jsonl(p) == list(tel.events)
    # byte-determinism: sorted keys, fixed separators
    assert p.read_text().splitlines() == tel.event_lines()

    off = tlm.Telemetry(tracing=False)
    assert off.event("tick", unit=1) == 0          # disabled: seq 0
    assert len(off.events) == 0


def test_default_hub_set_and_resolve():
    prev = tlm.get_default()
    mine = tlm.Telemetry()
    try:
        assert tlm.set_default(mine) is prev
        assert tlm.resolve(None) is mine
        assert tlm.resolve(prev) is prev
    finally:
        tlm.set_default(prev)


# ---------------------------------------------------------------------------
# trace_reduce: synthetic anomalies
# ---------------------------------------------------------------------------
def _ev(seq, kind, **kw):
    return {"seq": seq, "t": float(seq), "kind": kind, **kw}


def test_trace_reduce_closed_chain_and_anomalies():
    events = [
        # unit 1: clean closed chain
        _ev(1, "submit", unit=1),
        _ev(2, "dispatch", unit=1, worker="w1"),
        _ev(3, "report", unit=1, worker="w1"),
        _ev(4, "quorum", unit=1),
        # unit 2: submitted, dispatched, never reported -> unclosed
        _ev(5, "submit", unit=2),
        _ev(6, "dispatch", unit=2, worker="w1"),
        # unit 3: quorum with no dispatch -> quorum_without_lease
        _ev(7, "submit", unit=3),
        _ev(8, "quorum", unit=3),
        # unit 4: report from a worker that never held the lease
        _ev(9, "submit", unit=4),
        _ev(10, "dispatch", unit=4, worker="w1"),
        _ev(11, "report", unit=4, worker="forger"),
        _ev(12, "report", unit=4, worker="w1"),
        _ev(13, "quorum", unit=4),
        # unit 5: one attributed reissue (cause_seq -> fault), one not
        _ev(14, "worker_leave", worker="w2"),
        _ev(15, "reissue", unit=5, cause="worker_leave", cause_seq=14),
        _ev(16, "reissue", unit=5),                       # no cause
        _ev(17, "reissue", unit=5, cause="x", cause_seq=1),  # not a fault
    ]
    rep = tlm.trace_reduce(events, storm_threshold=3)
    kinds = rep.anomaly_kinds()
    assert kinds["unclosed_span"] == 2          # units 2 and 5
    assert kinds["quorum_without_lease"] == 1
    assert kinds["report_without_lease"] == 1
    assert kinds["unattributed_reissue"] == 2
    assert kinds["reissue_storm"] == 1          # unit 5 hit the threshold
    assert rep.reissues == 3 and rep.attributed == 1
    assert rep.completed == 3
    assert rep.units[1].closed() and not rep.units[2].closed()
    assert rep.units[2].stage() == "dispatch"
    assert "anomalies=7" in rep.summary()
    # require_fold flips closure for quorum-only chains
    assert not rep.units[1].closed(require_fold=True)
    assert tlm.trace_reduce(events + [_ev(18, "fold", unit=1)],
                            storm_threshold=99).units[1].closed(
                                require_fold=True)


def test_trace_reduce_cli_exit_codes(tmp_path):
    good = tmp_path / "good.jsonl"
    good.write_text(json.dumps(_ev(1, "submit", unit=1)) + "\n" +
                    json.dumps(_ev(2, "dispatch", unit=1, worker="w")) + "\n" +
                    json.dumps(_ev(3, "report", unit=1, worker="w")) + "\n" +
                    json.dumps(_ev(4, "quorum", unit=1)) + "\n")
    assert tlm.main([str(good)]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps(_ev(1, "submit", unit=1)) + "\n")
    assert tlm.main([str(bad), "--unit", "1"]) == 1


# ---------------------------------------------------------------------------
# scheduler + plane event emission
# ---------------------------------------------------------------------------
def test_scheduler_lease_expiry_reissue_is_attributed():
    clock = SimClock()
    tel = tlm.Telemetry(tracing=True, clock=clock)
    sched = VolunteerScheduler(deadline_s=10.0, clock=clock, telemetry=tel)
    sched.join("w1")
    sched.join("w2")
    sched.submit(0, {})
    assert sched.request_work("w1").unit_id == 0
    clock.advance(11.0)                       # w1's lease expires
    wu = sched.request_work("w2")
    assert wu is not None and wu.unit_id == 0
    sched.report("w2", 0, "h" * 40)
    assert sched.stats["lease_expiries"] == 1
    assert sched.stats["reissued"] == 1
    evs = list(tel.events)
    expiry = next(e for e in evs if e["kind"] == "lease_expire")
    reissue = next(e for e in evs if e["kind"] == "reissue")
    assert reissue["cause"] == "lease_expire"
    assert reissue["cause_seq"] == expiry["seq"]
    rep = tlm.trace_reduce(tel)
    assert rep.reissues == 1 and rep.attribution_rate == 1.0
    assert not rep.anomalies
    # the tracing path also populated the dispatch-latency histogram
    assert sched._dispatch_hist.count == 2


def test_shardplane_kill_shard_drops_point_at_the_kill_event():
    clock = SimClock()
    tel = tlm.Telemetry(tracing=True, clock=clock)
    plane = ShardedScheduler(shards=2, deadline_s=1000.0, watermark=1,
                             refill_batch=4, clock=clock, telemetry=tel)
    # one worker homed on each shard
    by_home, i = {}, 0
    while len(by_home) < 2:
        w = f"w{i}"
        i += 1
        by_home.setdefault(plane.home_shard(w), w)
    for w in by_home.values():
        plane.join(w)
    for uid in range(8):                      # slots split 4/4 across shards
        plane.submit(uid, {})
    # each worker's refill leases its home shard's units; no reports yet
    assert plane.request_work(by_home[0]) is not None
    assert plane.request_work(by_home[1]) is not None

    info = plane.fail_shard(1)
    assert info["reassigned_open"] == 4

    evs = list(tel.events)
    kill = next(e for e in evs if e["kind"] == "kill_shard")
    drops = [e for e in evs if e["kind"] == "lease_drop"]
    assert drops, "shard kill must drop the open leases it found"
    for d in drops:
        assert d["cause"] == "shard_kill"
        assert d["cause_seq"] == kill["seq"]
        assert d["shard"] == 1
    migrations = [e for e in evs if e["kind"] == "migrate"]
    assert len(migrations) == 4
    assert all(m["from_shard"] == 1 for m in migrations)

    # drive everything to completion on the survivor, then audit the trace
    guard = 0
    while not plane.done():
        guard += 1
        assert guard < 1000
        progressed = False
        for w in by_home.values():
            wu = plane.request_work(w)
            if wu is not None:
                progressed = True
                plane.report(w, wu.unit_id, "h" * 40)
        plane.flush_reports()
        if not progressed:
            clock.advance(plane.backoff_max_s + 1.0)
    rep = tlm.trace_reduce(tel)
    assert rep.completed == 8
    assert rep.attribution_rate == 1.0        # 100% of reissues attributed
    assert not rep.anomalies
    assert all(ch.closed() for ch in rep.units.values())


# ---------------------------------------------------------------------------
# toy training job (cheap, bitwise-deterministic) for the churn run
# ---------------------------------------------------------------------------
class ToyStream:
    def batch(self, index: int) -> dict:
        rng = np.random.default_rng(1000 + index)
        return {"x": rng.standard_normal(N).astype(np.float32)}


def _toy_grad(params, batch):
    diff = params["w"] - batch["x"]
    return float(np.mean(diff * diff)), {"w": (2.0 / N) * diff}


def _toy_apply(state, grads):
    m = (0.9 * state.opt["m"] + grads["w"]).astype(np.float32)
    w = (state.params["w"] - 0.1 * m).astype(np.float32)
    return api.TrainState({"w": w}, {"m": m})


def _toy_state():
    rng = np.random.default_rng(42)
    return api.TrainState({"w": rng.standard_normal(N).astype(np.float32)},
                          {"m": np.zeros(N, np.float32)})


def _churn_run(seed: int, dump_dir: Path):
    """One seeded churn run on an isolated hub; -> (event lines, report,
    final state bytes, trainer)."""
    clock = SimClock()
    tel = tlm.Telemetry(tracing=True, clock=clock)
    plane = ShardedScheduler(shards=2, deadline_s=30.0, watermark=1,
                             refill_batch=2, clock=clock, telemetry=tel)
    stores = [ChunkStore(chunk_bytes=CHUNK, telemetry=tel)
              for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:], telemetry=tel)
    sim = ChurnSim(rs, seed=seed, shards=plane, telemetry=tel,
                   dump_on_fault=dump_dir)
    snaps = SnapshotManager(rs, keep_last=10)
    tr = VolunteerTrainer(grad_fn=_toy_grad, apply_fn=_toy_apply,
                          state=_toy_state(), stream=ToyStream(),
                          micro_batches=2, scheduler=plane, snapshots=snaps,
                          snapshot_every=1, seed=seed, replicas=rs,
                          telemetry=tel)
    next_id = [0]

    def spawn(n):
        for _ in range(n):
            w = next_id[0]
            next_id[0] += 1
            tr.add_worker(SimWorker(
                f"vol-{w}", fail_prob=0.25,
                rng=np.random.default_rng((seed, w))))

    spawn(3)
    tr.respawn = lambda t: spawn(1)
    killed = []

    def on_sweep(t, step):
        # mid-round shard kill: fires while reports are buffered and the
        # watermark refill holds open leases on the doomed shard
        if step == 1 and not killed and plane.shard_alive[1]:
            sim.kill_shard(1)
            killed.append(step)

    tr.on_sweep = on_sweep
    for s in range(5):
        alive = sum(w.alive for w in tr.workers.values())
        if alive < 3:
            spawn(3 - alive)
        sim.hot(lambda s=s: tr.round(s))
        sim.deliver(shuffle=True)
        sim.settle()
        if s == 2:
            sim.kill(0, wipe=True)            # primary disk loss, mid-run
            sim.promote()
    lines = tel.event_lines()
    rep = tlm.trace_reduce(tel)
    return lines, rep, tr


def test_churn_run_closed_chains_full_attribution_and_determinism(tmp_path):
    lines_a, rep, tr = _churn_run(3, tmp_path / "a")
    lines_b, rep_b, _ = _churn_run(3, tmp_path / "b")

    # byte-identical event streams from one seed
    assert lines_a == lines_b
    # ...and a different seed actually changes the schedule
    lines_c, _, _ = _churn_run(4, tmp_path / "c")
    assert lines_a != lines_c

    # the scenario exercised real churn: shard kill + wipe + reissues
    kinds = {e["kind"] for e in map(json.loads, lines_a)}
    assert {"kill_shard", "wipe", "promote", "member_down"} <= kinds
    assert rep.reissues > 0

    # every completed unit folded through a closed chain, every reissue
    # is attributed to a recorded fault event, and nothing is anomalous
    assert rep.folded == 5 * 2                # 5 rounds x micro_batches
    assert rep.attribution_rate == 1.0
    assert rep.anomalies == []
    for ch in rep.units.values():
        if ch.quorums:
            assert ch.closed(require_fold=True)

    # ChurnSim dumped the recorder on each fault step
    dumps = sorted((tmp_path / "a").glob("fault-*.jsonl"))
    assert dumps, "dump_on_fault must write a JSONL per fault"
    assert any("kill_shard" in d.name for d in dumps)
    # each dump is a loadable prefix of the final stream
    first = tlm.load_jsonl(dumps[0])
    assert first and first[-1]["seq"] <= json.loads(lines_a[-1])["seq"]

    # trainer-side flight recorder dump round-trips through trace_reduce
    out = tmp_path / "final.jsonl"
    assert tr.dump_flight_recorder(out) == len(lines_a)
    rep2 = tlm.trace_reduce(tlm.load_jsonl(out))
    assert rep2.folded == rep.folded and rep2.anomalies == []


# ---------------------------------------------------------------------------
# RoundStats: registry-delta derivation
# ---------------------------------------------------------------------------
def test_roundstats_fields_come_from_registry_deltas():
    clock = SimClock()
    tel = tlm.Telemetry(clock=clock)
    primary = ChunkStore(chunk_bytes=CHUNK, telemetry=tel)
    peer = ChunkStore(chunk_bytes=CHUNK, telemetry=tel)
    rs = ReplicaSet(primary, [peer], telemetry=tel)
    snaps = SnapshotManager(rs, keep_last=10)
    tr = VolunteerTrainer(
        grad_fn=_toy_grad, apply_fn=_toy_apply, state=_toy_state(),
        stream=ToyStream(), micro_batches=2, snapshots=snaps,
        snapshot_every=1, seed=0, replicas=rs, telemetry=tel,
        scheduler=VolunteerScheduler(clock=clock, telemetry=tel))
    tr.add_worker(SimWorker("w0"))
    st0 = tr.round(0)
    st1 = tr.round(1)
    # replicated/read_repairs are per-round deltas of the replica scope
    assert st0.replicated > 0                  # round-0 snapshot fanned out
    assert st0.replicated + st1.replicated == rs.rstats["sent"]
    assert st0.read_repairs == 0
    assert st0.lease_expiries == 0 and st0.reissued == 0
    assert st0.units == 2 and st1.step == 1
    # the trainer scope counted the folds the rounds consumed
    assert tr.tstats["folds"] == 4


def test_roundstats_counts_lease_expiries():
    clock = SimClock()
    tel = tlm.Telemetry(clock=clock)
    sched = VolunteerScheduler(deadline_s=5.0, clock=clock, telemetry=tel)
    tr = VolunteerTrainer(
        grad_fn=_toy_grad, apply_fn=_toy_apply, state=_toy_state(),
        stream=ToyStream(), micro_batches=1, seed=0,
        scheduler=sched, telemetry=tel)
    # a worker that always dies holding its lease, plus a healthy one:
    # the death reissues its unit (counted by the round's registry delta)
    tr.add_worker(SimWorker("dead", fail_prob=1.0,
                            rng=np.random.default_rng(1)))
    tr.add_worker(SimWorker("ok"))
    st = tr.round(0)
    assert st.step == 0 and st.units == 1
    assert st.reissued + st.duplicates >= 0
    assert isinstance(st.lease_expiries, int) and st.lease_expiries >= 0
    assert isinstance(st.read_repairs, int) and st.read_repairs == 0
    assert st.lease_expiries == sched.stats["lease_expiries"]
    assert st.reissued == sched.stats["reissued"]


# ---------------------------------------------------------------------------
# CI tooling: regression gate kind + stats-mutation lint
# ---------------------------------------------------------------------------
def _load_module(path: Path, name: str):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_telemetry_kind(tmp_path):
    sys.modules.setdefault("benchmarks", __import__("types").ModuleType(
        "benchmarks"))
    cr = _load_module(REPO / "benchmarks" / "check_regression.py",
                      "_cr_telemetry_test")
    base = {"kind": "telemetry", "overhead_ratio": 1.5,
            "rows": [{"name": "disabled", "p50_us": 3.0},
                     {"name": "enabled", "p50_us": 4.5}]}
    ok = {"kind": "telemetry", "overhead_ratio": 2.0,
          "rows": [{"name": "disabled", "p50_us": 3.5},
                   {"name": "enabled", "p50_us": 7.0}]}
    assert cr.check_telemetry(ok, base, tolerance=0.25, floor_us=100.0,
                              overhead_limit=3.0) == []
    # within-run ratio breach fails regardless of absolute timings
    hot = dict(ok, overhead_ratio=4.2)
    fails = cr.check_telemetry(hot, base, tolerance=0.25, floor_us=100.0,
                               overhead_limit=3.0)
    assert any("overhead_ratio" in f for f in fails)
    # disabled-path p50 regression vs baseline fails too
    slow = {"kind": "telemetry", "overhead_ratio": 1.2,
            "rows": [{"name": "disabled", "p50_us": 500.0},
                     {"name": "enabled", "p50_us": 600.0}]}
    fails = cr.check_telemetry(slow, base, tolerance=0.25, floor_us=10.0,
                               overhead_limit=3.0)
    assert any("disabled" in f for f in fails)
    # end-to-end: main() dispatches on kind and exits clean
    cur = tmp_path / "cur.json"
    basef = tmp_path / "base.json"
    cur.write_text(json.dumps(ok))
    basef.write_text(json.dumps(base))
    assert cr.main([str(cur), "--baseline", str(basef)]) == 0
    cur.write_text(json.dumps(hot))
    assert cr.main([str(cur), "--baseline", str(basef)]) == 1


def test_stats_mutation_lint(tmp_path):
    lint = _load_module(REPO / "tools" / "lint_stats_mutations.py",
                        "_lint_stats_test")
    bad = tmp_path / "bad.py"
    bad.write_text("class A:\n"
                   "    def f(self):\n"
                   "        self.stats['x'] += 1\n"
                   "        self.rstats['y'] = 2\n"
                   "        self.plane_stats['z'] -= 3\n"
                   "        other.tstats['w'] += 4\n"
                   "        fine['a'] += 5\n"              # not a stats name
                   "        self.stats = {}\n")            # rebind is fine
    failures = lint.lint_paths([bad])
    assert len(failures) == 4
    assert all("read-only" in f for f in failures)
    # telemetry.py itself is exempt wherever it lives
    exempt = tmp_path / "telemetry.py"
    exempt.write_text("stats = {}\nstats['x'] = 1\n")
    assert lint.lint_paths([tmp_path]) == failures
    # the real tree is clean — the converted subsystems have no bare
    # stats mutations left
    assert lint.lint_paths([REPO / "src"]) == []
    # CLI contract: violations exit 1 with file:line diagnostics
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint_stats_mutations.py"),
         str(bad)], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "bad.py:3" in proc.stderr
