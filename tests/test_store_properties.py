"""Property-based round-trip tests (hypothesis) for the chunk-store wire
formats: zero-run RLE payloads and packed ``DeltaRecord``s — including
empty payloads, all-zero pages, and payloads with no zero runs at all.
"""
import pytest

pytest.importorskip("hypothesis")

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.chunkstore import (DeltaRecord, rle_zero_decode,
                                   rle_zero_encode)

SETTINGS = dict(max_examples=60, deadline=None)


# ---------------------------------------------------------------------------
# zero-run RLE
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(data=st.binary(max_size=4096))
def test_rle_roundtrip_arbitrary(data):
    assert rle_zero_decode(rle_zero_encode(data), len(data)) == data


@settings(**SETTINGS)
@given(n=st.integers(0, 1 << 14))
def test_rle_all_zero_page(n):
    data = bytes(n)
    enc = rle_zero_encode(data)
    assert rle_zero_decode(enc, n) == data
    # one zero-run token; short runs fold into a literal; empty stays empty
    assert len(enc) == (0 if n == 0 else 5 if n >= 8 else n + 5)


@settings(**SETTINGS)
@given(vals=st.lists(st.integers(1, 255), min_size=1, max_size=2048))
def test_rle_no_zero_runs_bails_to_literal(vals):
    data = bytes(vals)
    enc = rle_zero_encode(data)
    assert len(enc) == len(data) + 5          # single literal token, O(1)
    assert rle_zero_decode(enc, len(data)) == data


@settings(**SETTINGS)
@given(runs=st.lists(st.tuples(st.booleans(), st.integers(1, 300)),
                     max_size=24),
       seed=st.integers(0, 2 ** 31))
def test_rle_roundtrip_structured_runs(runs, seed):
    """Alternating zero / nonzero runs of arbitrary lengths — the XOR
    payload shape RLE exists for."""
    rng = np.random.default_rng(seed)
    parts = [np.zeros(n, np.uint8) if zero
             else rng.integers(1, 256, n, dtype=np.uint8).astype(np.uint8)
             for zero, n in runs]
    data = (np.concatenate(parts) if parts
            else np.zeros(0, np.uint8)).tobytes()
    assert rle_zero_decode(rle_zero_encode(data), len(data)) == data


# ---------------------------------------------------------------------------
# DeltaRecord pack/unpack
# ---------------------------------------------------------------------------
_hex = st.text(alphabet="0123456789abcdef", min_size=0, max_size=64)
_parents = st.one_of(_hex, _hex.map(lambda s: "d:" + s))


@settings(**SETTINGS)
@given(parent=_parents, depth=st.integers(0, 0xFFFF),
       raw_len=st.integers(0, 0xFFFFFFFF),
       payload=st.binary(max_size=2048), compressed=st.booleans())
def test_delta_record_pack_unpack_roundtrip(parent, depth, raw_len,
                                            payload, compressed):
    rec = DeltaRecord(parent, depth, raw_len, payload, compressed)
    out = DeltaRecord.unpack(rec.pack())
    assert out == rec                         # all five fields survive


@settings(**SETTINGS)
@given(data=st.binary(max_size=4096))
def test_delta_record_xor_through_rle(data):
    """A packed record reproduces its XOR image whichever encoding the
    writer chose (including the empty payload)."""
    comp = DeltaRecord("ff" * 32, 1, len(data), rle_zero_encode(data), True)
    assert DeltaRecord.unpack(comp.pack()).xor() == data
    raw = DeltaRecord("ff" * 32, 1, len(data), data, False)
    assert DeltaRecord.unpack(raw.pack()).xor() == data
