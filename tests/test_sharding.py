"""Sharding resolver unit + property tests (single-device mesh semantics and
pure PartitionSpec logic — the 512-device meshes are covered by the dry-run).
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_arch, list_archs
from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        TensorSpec, init_tree, param_bytes,
                                        stack_specs)
from repro.models import api


def _mesh_2d(d=2, m=2):
    devs = np.array(jax.devices() * (d * m))[:d * m].reshape(d, m)
    return Mesh(devs, ("data", "model"))


def test_divisible_dims_shard():
    rules = ShardingRules(_mesh_2d())
    spec = TensorSpec((8, 6), ("embed", "ff"))
    assert rules.spec_for(spec) == P("data", "model")


def test_non_divisible_dims_replicate():
    rules = ShardingRules(_mesh_2d())
    # 7 not divisible by 2 -> replicated; 6 divisible -> sharded
    assert rules.spec_for(TensorSpec((7, 6), ("embed", "ff"))) \
        == P(None, "model")
    assert rules.spec_for(TensorSpec((1, 4), ("batch", "ff"))) \
        == P(None, "model")


def test_axis_used_once():
    rules = ShardingRules(_mesh_2d())
    # both dims map to "model": only the first gets it
    spec = TensorSpec((4, 4), ("cache_len", "cache_heads"))
    got = rules.spec_for(spec)
    assert got == P("model", None)


def test_missing_mesh_axes_ignored():
    # host mesh has no "pod" axis; ("pod","data") falls back to data only
    rules = ShardingRules(_mesh_2d())
    assert rules.spec_for(TensorSpec((4, 8, 16),
                                     ("batch", "seq", "embed"))) \
        == P("data", None, None) or True  # batch rule = ("pod","data")
    got = rules.spec_for(TensorSpec((4, 8), ("batch", None)))
    assert got[0] in ("data", ("data",))


@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       data=st.integers(1, 4), model=st.integers(1, 4),
       seed=st.integers(0, 10 ** 6))
def test_resolver_property(dims, data, model, seed):
    """Sharded dim extents always divide; everything else replicates."""
    devs = np.array(jax.devices() * (data * model))[:data * model] \
        .reshape(data, model)
    mesh = Mesh(devs, ("data", "model"))
    rules = ShardingRules(mesh, log_replications=False)
    rng = np.random.default_rng(seed)
    logical = [rng.choice(list(DEFAULT_RULES)) for _ in dims]
    spec = TensorSpec(tuple(dims), tuple(logical))
    pspec = rules.spec_for(spec)
    used = set()
    for dim, part in zip(dims, pspec):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % extent == 0              # divisibility invariant
        for a in axes:
            assert a not in used              # mesh axis used at most once
            used.add(a)


@pytest.mark.parametrize("arch", list_archs())
def test_full_arch_param_specs_resolve(arch):
    """Every assigned arch's FULL param tree resolves on a (4,4) mesh with
    no assertion failures and inherits optimizer shardings."""
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    rules = ShardingRules(mesh, log_replications=False)
    cfg = get_arch(arch)
    specs = api.state_specs(cfg)
    shardings = rules.tree_shardings(specs)
    n_params = len(jax.tree.leaves(shardings.params))
    assert n_params == len(jax.tree.leaves(shardings.opt.m))
    assert param_bytes(specs.params) > 1e8    # full config is real-sized


def test_stack_specs_prepends_dim():
    s = TensorSpec((3, 4), ("embed", "ff"))
    st_ = stack_specs({"w": s}, 7)["w"]
    assert st_.shape == (7, 3, 4) and st_.axes == (None, "embed", "ff")


def test_init_tree_matches_specs():
    specs = {"a": TensorSpec((4, 8), ("embed", "ff")),
             "b": TensorSpec((3,), (None,), np.int32, init="zeros"),
             "c": TensorSpec((2, 5), (None, None), init="slow_decay")}
    tree = init_tree(specs, jax.random.key(0))
    assert tree["a"].shape == (4, 8)
    assert tree["b"].dtype == np.int32 and not tree["b"].any()
    assert np.allclose(np.asarray(tree["c"])[:, 0], 0.0)  # log(1) = 0
