"""Model-level properties: causality, batch-permutation equivariance, and
padding invariance — hypothesis-driven on reduced configs."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch, reduced
from repro.distributed.sharding import init_tree
from repro.models import api, lm
from repro.models.lm import RunConfig

RUN = RunConfig(remat="none", block_kv=8, ssm_chunk=8,
                compute_dtype=jnp.float32)
ARCHS = ["granite-3-2b", "falcon-mamba-7b", "hymba-1.5b"]


@pytest.fixture(scope="module")
def models():
    out = {}
    for a in ARCHS:
        cfg = reduced(get_arch(a))
        out[a] = (cfg, init_tree(api.param_specs(cfg), jax.random.key(0)))
    return out


@pytest.mark.parametrize("arch", ARCHS)
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31), cut=st.integers(2, 14))
def test_causality(models, arch, seed, cut):
    """Changing tokens AFTER position `cut` never changes logits at <= cut."""
    cfg, params = models[arch]
    r = np.random.default_rng(seed)
    toks = r.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, cut:] = r.integers(0, cfg.vocab_size, (1, 16 - cut))
    la, _ = lm.forward_train(params, cfg, toks, RUN)
    lb, _ = lm.forward_train(params, cfg, toks2, RUN)
    np.testing.assert_allclose(np.asarray(la[:, :cut]),
                               np.asarray(lb[:, :cut]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_batch_permutation_equivariance(models, arch):
    cfg, params = models[arch]
    r = np.random.default_rng(3)
    toks = r.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)
    perm = np.array([2, 0, 3, 1])
    la, _ = lm.forward_train(params, cfg, toks, RUN)
    lb, _ = lm.forward_train(params, cfg, toks[perm], RUN)
    np.testing.assert_allclose(np.asarray(la[perm]), np.asarray(lb),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_block_size_invariance(models, arch):
    """Attention/SSM chunk sizes are numerics-neutral execution knobs."""
    cfg, params = models[arch]
    r = np.random.default_rng(4)
    toks = r.integers(0, cfg.vocab_size, (2, 24)).astype(np.int32)
    base, _ = lm.forward_train(params, cfg, toks, RUN)
    for bk, sc in [(4, 4), (16, 12), (64, 24)]:
        alt, _ = lm.forward_train(
            params, cfg, toks,
            RunConfig(remat="none", block_kv=bk, ssm_chunk=sc,
                      compute_dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(base), np.asarray(alt),
                                   rtol=2e-5, atol=2e-5)


def test_windowed_attention_limits_context():
    """With window w, logits at position i depend only on tokens > i - w."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_arch("granite-3-2b")), window=4)
    params = init_tree(api.param_specs(cfg), jax.random.key(1))
    r = np.random.default_rng(5)
    toks = r.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    toks2 = toks.copy()
    toks2[:, 0:4] = r.integers(0, cfg.vocab_size, (1, 4))  # outside window
    la, _ = lm.forward_train(params, cfg, toks, RUN)
    lb, _ = lm.forward_train(params, cfg, toks2, RUN)
    # position 12 attends to positions 9..12 only -> unchanged
    np.testing.assert_allclose(np.asarray(la[:, 12:]),
                               np.asarray(lb[:, 12:]),
                               rtol=1e-5, atol=1e-5)
