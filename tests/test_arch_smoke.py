"""Per-architecture smoke tests: every assigned arch (reduced, same family)
runs one forward + one train step on CPU; output shapes + finite values.
The FULL configs are exercised only by the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, list_archs, reduced
from repro.distributed.sharding import init_tree
from repro.models import api, encdec, lm
from repro.models.lm import RunConfig
from repro.optim import adamw

RUN = RunConfig(remat="none", block_kv=16, ssm_chunk=8)
ALL_ARCHS = list_archs()


def _batch(cfg, b=2, t=16, seed=0):
    r = np.random.default_rng(seed)
    out = {"tokens": r.integers(0, cfg.vocab_size, (b, t)).astype(np.int32),
           "labels": r.integers(0, cfg.vocab_size, (b, t)).astype(np.int32)}
    if cfg.enc_dec:
        out["frames"] = r.standard_normal((b, t, cfg.d_model)).astype(
            np.float32)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_arch(arch))
    params = init_tree(api.param_specs(cfg), jax.random.key(0))
    batch = _batch(cfg)
    if cfg.enc_dec:
        logits, _ = encdec.forward_train(params, cfg, batch["frames"],
                                         batch["tokens"], RUN)
    else:
        logits, _ = lm.forward_train(params, cfg, batch["tokens"], RUN)
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(arch):
    cfg = reduced(get_arch(arch))
    specs = api.state_specs(cfg)
    state = api.TrainState(init_tree(specs.params, jax.random.key(0)),
                           init_tree(specs.opt, jax.random.key(1)))
    step = jax.jit(api.make_train_step(
        cfg, RUN, adamw.AdamWConfig(warmup_steps=1)))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_near_uniform_at_init(arch):
    cfg = reduced(get_arch(arch))
    params = init_tree(api.param_specs(cfg), jax.random.key(2))
    loss = api.make_eval_loss(cfg, RUN)(params, _batch(cfg, seed=3))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2-1.5b",
                                  "falcon-mamba-7b", "hymba-1.5b",
                                  "deepseek-moe-16b", "seamless-m4t-medium"])
def test_prefill_decode_matches_teacher_forced(arch):
    cfg = reduced(get_arch(arch))
    run = RunConfig(remat="none", block_kv=8, ssm_chunk=8,
                    compute_dtype=jnp.float32, capacity_factor=8.0)
    params = init_tree(api.param_specs(cfg), jax.random.key(1))
    B, T, MAX = 2, 12, 20
    r = np.random.default_rng(1)
    toks = r.integers(0, cfg.vocab_size, (B, T + 1)).astype(np.int32)
    batch = {"tokens": toks[:, :T]}
    if cfg.enc_dec:
        frames = r.standard_normal((B, T, cfg.d_model)).astype(np.float32)
        batch["frames"] = frames
        full, _ = encdec.forward_train(params, cfg, frames, toks, run)
    else:
        full, _ = lm.forward_train(params, cfg, toks, run)
    last, caches = api.make_prefill_step(cfg, MAX, run)(params, batch)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, T - 1]),
                               rtol=5e-3, atol=5e-3)
    dl, _ = api.make_decode_step(cfg, run)(
        params, caches, {"tokens": toks[:, T:T + 1], "index": jnp.int32(T)})
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full[:, T]),
                               rtol=5e-3, atol=5e-3)


def test_moe_routing_stats():
    cfg = reduced(get_arch("deepseek-moe-16b"))
    params = init_tree(api.param_specs(cfg), jax.random.key(0))
    logits, metrics = lm.forward_train(
        params, cfg, _batch(cfg)["tokens"], RUN)
    assert float(metrics["moe_drop_frac"]) < 0.5
    assert float(metrics["moe_aux"]) > 0.5     # ~1.0 when balanced
