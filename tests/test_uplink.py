"""Delta-aware uplink tests: store written from BOTH ends.

Concurrent-writer invariants (two clients against one parent, chain-cap
rebase racing GC, v1→v2 restore after an uplink-written round), the
encode → plan_recv → recv → resolve protocol (Wire), server-side quorum
folding, and the trainer's round loop with per-worker uplink credit.
"""
import threading
from typing import NamedTuple

import numpy as np
import pytest

from repro.core.chunkstore import ChunkStore, is_delta_ref
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.snapshots import Manifest, SnapshotManager
from repro.core.uplink import (UplinkEncoder, decode_update, leaf_image,
                               push_update)
from repro.optim import grad_compress


def _xor(a: bytes, b: bytes) -> bytes:
    return (np.frombuffer(a, np.uint8) ^ np.frombuffer(b, np.uint8)).tobytes()


# ---------------------------------------------------------------------------
# concurrent-writer store invariants
# ---------------------------------------------------------------------------
def test_two_clients_put_delta_against_same_parent():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    server = ChunkStore(chunk_bytes=1 << 12)
    parent = server.put(base)

    results = {}
    for cid, pos in (("volA", 7), ("volB", 2049)):
        client = ChunkStore(chunk_bytes=1 << 12)
        assert client.put(base) == parent          # shared ancestry
        new = bytearray(base)
        new[pos] ^= 0xFF
        ref = client.put_delta(parent, _xor(base, bytes(new)),
                               full_bytes=bytes(new))
        assert is_delta_ref(ref)
        offered = {r: client.object_size(r)
                   for r in client.live_closure([ref])}
        needed, moved, dedup = server.plan_recv(offered, client_id=cid)
        assert parent not in needed                # server already holds it
        server.recv(client.send(needed), client_id=cid)
        results[cid] = (ref, bytes(new))

    # both children of the same parent coexist and resolve bit-exactly
    for cid, (ref, want) in results.items():
        assert server.resolve(ref) == want
        assert server.uplinks[cid]["bytes_in"] > 0

    # a third client replaying volA's exact delta moves ZERO bytes
    replay = ChunkStore(chunk_bytes=1 << 12)
    replay.put(base)
    ref = replay.put_delta(parent, _xor(base, results["volA"][1]))
    offered = {r: replay.object_size(r) for r in replay.live_closure([ref])}
    needed, moved, dedup = server.plan_recv(offered, client_id="volC")
    assert not needed and moved == 0 and dedup > 0


def test_chain_cap_rebase_races_gc():
    store = ChunkStore(chunk_bytes=1 << 12, max_chain=3)
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    head = [store.put(data)]
    lock = threading.Lock()      # the server's request serialization point
    stop = threading.Event()
    errors = []

    def collector():
        while not stop.is_set():
            try:
                with lock:
                    store.gc({head[0]})
            except Exception as e:        # pragma: no cover - fail loudly
                errors.append(e)
                return

    t = threading.Thread(target=collector)
    t.start()
    cur = data
    try:
        for i in range(150):              # 150/3 -> dozens of rebases
            new = bytearray(cur)
            new[i % 4096] ^= 0xFF
            new = bytes(new)
            with lock:
                head[0] = store.put_delta(head[0], _xor(cur, new),
                                          full_bytes=new)
            cur = new
    finally:
        stop.set()
        t.join()
    assert not errors
    assert store.stats["rebased"] > 10
    assert store.ref_depth(head[0]) <= 3
    assert store.resolve(head[0]) == cur   # GC never ate a live parent


def test_v1_to_v2_restore_after_uplink_round():
    """An uplink-written round must not disturb v1 restores, and v2
    snapshots taken afterwards share the same store."""
    import json

    store = ChunkStore(chunk_bytes=1 << 12)
    arr = np.arange(8_000, dtype=np.float32)
    hashes = store.put_buffer(memoryview(arr).cast("B"))
    v1 = json.dumps({
        "snapshot_id": "snap-000001-cafef00d", "parent": None,
        "step": 1, "created": 0.0, "kind": "base",
        "aux": {"cursor": {"next_index": 2}},
        "tensors": {"['x']": {"shape": [8000], "dtype": "float32",
                              "hashes": hashes}},
    })

    # a volunteer round lands delta objects in the same store
    g = {"w": np.random.default_rng(2).standard_normal(50_000)
         .astype(np.float32)}
    enc = UplinkEncoder(chunk_bytes=1 << 12)
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    push_update(enc.encode(comp), store, client_id="vol")
    g["w"][3] += 1.0
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    upd = enc.encode(comp)
    push_update(upd, store, client_id="vol")
    assert any(is_delta_ref(r) for r in upd.all_refs())

    mgr = SnapshotManager(store, keep_last=10, auto_gc=False)
    man = Manifest.from_json(v1)
    mgr.manifests[man.snapshot_id] = man
    mgr.order.append(man.snapshot_id)
    got, aux = mgr.restore(target_tree={"x": np.zeros_like(arr)})
    assert np.array_equal(got["x"], arr)           # v1 path intact
    y = arr.copy()
    y[77] = -1.0
    mgr.snapshot({"x": y}, step=2)                 # v2 diff on the same store
    got, _ = mgr.restore(target_tree={"x": np.zeros_like(arr)})
    assert np.array_equal(got["x"], y)
    assert decode_update(store, upd)               # uplink chains still live


# ---------------------------------------------------------------------------
# ingest validation: tampered + dangling records never land
# ---------------------------------------------------------------------------
def test_ingest_rejects_tampered_and_dangling_records():
    server = ChunkStore(chunk_bytes=1 << 12)
    client = ChunkStore(chunk_bytes=1 << 12)
    base = bytes(np.random.default_rng(3).integers(0, 256, 4096,
                                                   dtype=np.uint8))
    parent = client.put(base)
    new = bytearray(base)
    new[1] ^= 0x55
    ref = client.put_delta(parent, _xor(base, bytes(new)),
                           full_bytes=bytes(new))

    recs = client.send([ref, parent])
    tampered = dict(recs)
    tampered[ref] = tampered[ref][:-1] + bytes([tampered[ref][-1] ^ 1])
    with pytest.raises(IOError):
        server.recv(tampered, client_id="evil")
    assert not server.has(ref) and not server.has(parent)  # none landed

    dangling = {ref: recs[ref]}            # delta without its parent
    with pytest.raises(IOError):
        server.recv(dangling, client_id="evil")
    server.recv(recs, client_id="ok")    # the honest batch lands whole
    assert server.resolve(ref) == bytes(new)


# ---------------------------------------------------------------------------
# encoder: sparse update moves less than the dense int8 payload
# ---------------------------------------------------------------------------
def test_uplink_sparse_update_beats_dense_wire():
    rng = np.random.default_rng(4)
    g = {"w": rng.standard_normal(200_000).astype(np.float32)}
    enc = UplinkEncoder(chunk_bytes=1 << 12)
    server = ChunkStore(chunk_bytes=1 << 12)
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    push_update(enc.encode(comp), server, client_id="vol")

    g2 = {"w": g["w"].copy()}
    g2["w"][:32] *= 2.0                          # one quantization block
    comp2, _ = grad_compress.compress(g2, grad_compress.init_error(g2))
    upd = enc.encode(comp2)
    moved, dedup = push_update(upd, server, client_id="vol")
    assert 0 < moved < upd.dense_bytes           # the acceptance bound
    assert dedup > 0
    # the server reconstructs the quantized image bit-exactly
    dec = decode_update(server, upd)
    for key, c in dec.items():
        want = {"['w']": comp2["w"]}[key]
        assert leaf_image(c).tobytes() == leaf_image(want).tobytes()


# ---------------------------------------------------------------------------
# server: report_result(update=...) validates, dedups, folds canonical
# ---------------------------------------------------------------------------
def _server_with_project(quorum=2):
    from repro.core.capsule import CapsuleSpec
    from repro.core.server import Project, VBoincServer
    from repro.models.lm import RunConfig

    sched = VolunteerScheduler(replication=quorum, quorum=quorum,
                               clock=SimClock())
    server = VBoincServer(ChunkStore(chunk_bytes=1 << 12))
    spec = CapsuleSpec("qwen2-1.5b", "train_4k", RunConfig())
    server.publish(Project("toy", spec, scheduler=sched))
    return server, sched


def test_server_quorum_folds_canonical_update():
    server, sched = _server_with_project(quorum=2)
    g = {"w": np.random.default_rng(5).standard_normal(60_000)
         .astype(np.float32)}
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    img = leaf_image(comp["w"]).tobytes()

    sched.join("a")
    sched.join("b")
    sched.submit(0, {})
    sched.request_work("a")
    sched.request_work("b")
    ups = {}
    for wid in ("a", "b"):
        enc = UplinkEncoder(chunk_bytes=1 << 12)
        ups[wid] = enc.encode(comp)
        assert server.report_result("toy", wid, 0, "H",
                                    update=ups[wid]) == (wid == "b")
    proj = server.projects["toy"]
    assert 0 in proj.canonical_updates
    dec = server.resolve_round_update("toy", 0)
    assert leaf_image(dec["['w']"]).tobytes() == img
    # identical quantized images: the second volunteer moved ~no new chunks
    log = server.uplinks["toy"]
    assert log.accepted == 2 and log.rejected == 0
    assert server.store.uplinks["b"]["bytes_dedup"] > 0
    assert (server.store.uplinks["b"]["bytes_in"]
            < server.store.uplinks["a"]["bytes_in"] / 10)


def test_ingest_rejects_lied_delta_depth():
    """Depth is hashed into the record, so a lie survives the hash check;
    ingest must still reject it or max_chain accounting is poisoned."""
    from repro.core.chunkstore import DELTA_PREFIX, DeltaRecord, sha256

    server = ChunkStore(chunk_bytes=1 << 12)
    base = bytes(np.random.default_rng(7).integers(0, 256, 4096,
                                                   dtype=np.uint8))
    parent = server.put(base)
    xor = bytes([1]) + bytes(4095)
    for lied in (0, 7):          # true depth of a child of a raw ref is 1
        rec = DeltaRecord(parent, lied, len(xor), xor, False).pack()
        ref = DELTA_PREFIX + sha256(rec)
        with pytest.raises(IOError, match="depth"):
            server.recv({ref: rec}, client_id="evil")
        assert not server.has(ref)
    honest = DeltaRecord(parent, 1, len(xor), xor, False).pack()
    ref = DELTA_PREFIX + sha256(honest)
    server.recv({ref: honest}, client_id="ok")
    assert server.ref_depth(ref) == 1


def test_uplink_credit_waits_for_quorum():
    """A worker whose result fails validation earns no transfer credit
    even though its (valid-looking) bytes were ingested."""
    from repro.core.capsule import CapsuleSpec
    from repro.core.server import Project, VBoincServer
    from repro.models.lm import RunConfig

    sched = VolunteerScheduler(replication=3, quorum=2, clock=SimClock())
    server = VBoincServer(ChunkStore(chunk_bytes=1 << 12))
    spec = CapsuleSpec("qwen2-1.5b", "train_4k", RunConfig())
    server.publish(Project("toy", spec, scheduler=sched))
    state = _ToyState({"w": np.zeros(150_000, np.float32)})
    tr = VolunteerTrainer(grad_fn=_toy_grad_fn, apply_fn=_toy_apply_fn,
                          state=state, stream=_ToyStream(), micro_batches=1,
                          server=server, project="toy", uplink=True,
                          uplink_chunk_bytes=1 << 12)
    liar = SimWorker("liar", corrupt_prob=1.0)
    honest = [SimWorker("h0"), SimWorker("h1")]
    for w in [liar] + honest:
        tr.add_worker(w)
    sched.submit(0, {})
    unit = type("U", (), {"unit_id": 0})()
    g = _toy_grad_fn(state.params, {"i": np.int64(0)})[1]
    for w in [liar] + honest:
        sched.request_work(w.worker_id)
        tr._execute_unit_uplink(w, unit, 0.0, g)
    tr._settle_uplink_credit(sched.drain_completed())
    assert sched.workers["liar"].credit == 0.0        # bytes ingested, but
    assert sched.workers["liar"].uplink_bytes == 0    # no credit granted
    assert sched.workers["h0"].credit > 0 or sched.workers["h1"].credit > 0


def test_inflated_offer_cannot_mint_credit():
    """bytes_in comes from server-verified ingest bytes, never the
    client's claimed sizes — an inflated offer earns nothing extra."""
    server = ChunkStore(chunk_bytes=1 << 12)
    client = ChunkStore(chunk_bytes=1 << 12)
    data = bytes(np.random.default_rng(6).integers(0, 256, 4096,
                                                   dtype=np.uint8))
    ref = client.put(data)
    needed, moved, _ = server.plan_recv({ref: 10**12}, client_id="greedy")
    assert moved == 10**12                 # the claim, planning only
    server.recv(client.send(needed), client_id="greedy")
    assert server.uplinks["greedy"]["bytes_in"] == len(data)


def test_decode_failure_claws_back_credit():
    """An update that ingests cleanly but cannot decode (bad leaf meta)
    is rejected AND earns no transfer credit."""
    server, sched = _server_with_project(quorum=1)
    g = {"w": np.ones(30_000, np.float32)}
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    upd = UplinkEncoder(chunk_bytes=1 << 12).encode(comp)
    key = next(iter(upd.meta))
    upd.meta[key].blocks += 1              # records valid, meta lies
    sched.join("liar")
    sched.submit(0, {})
    sched.request_work("liar")
    assert not server.report_result("toy", "liar", 0, "H", update=upd)
    assert not sched.units[0].completed
    assert server.uplinks["toy"].rejected == 1
    log = server.store.uplinks["liar"]
    assert log["bytes_in"] == 0 and log["bytes_dedup"] == 0
    assert log["rejected"] == 1


def test_server_rejects_corrupt_update_before_scheduler():
    server, sched = _server_with_project(quorum=1)
    g = {"w": np.ones(30_000, np.float32)}
    comp, _ = grad_compress.compress(g, grad_compress.init_error(g))
    enc = UplinkEncoder(chunk_bytes=1 << 12)
    upd = enc.encode(comp)
    # flip one bit inside the client store: export ships a record whose
    # hash no longer matches its ref
    h = next(iter(upd.store._mem))
    upd.store._mem[h] = upd.store._mem[h][:-1] + bytes(
        [upd.store._mem[h][-1] ^ 1])
    sched.join("liar")
    sched.submit(0, {})
    sched.request_work("liar")
    assert not server.report_result("toy", "liar", 0, "H", update=upd)
    assert not sched.units[0].completed            # scheduler never saw it
    assert server.uplinks["toy"].rejected == 1
    assert server.store.uplinks["liar"]["bytes_in"] == 0   # clawed back


# ---------------------------------------------------------------------------
# scheduler: incremental completion view
# ---------------------------------------------------------------------------
def test_drain_completed_is_incremental():
    s = VolunteerScheduler(clock=SimClock())
    s.join("w")
    for uid in range(3):
        s.submit(uid, {})
        s.request_work("w")
        s.report("w", uid, "H")
    assert s.drain_completed() == [(0, "H"), (1, "H"), (2, "H")]
    assert s.drain_completed() == []               # drained, not re-scanned
    s.submit(3, {})
    s.request_work("w")
    s.report("w", 3, "H")
    assert s.drain_completed() == [(3, "H")]


# ---------------------------------------------------------------------------
# trainer end-to-end: rounds stream deltas, credit tracks deduped bytes
# ---------------------------------------------------------------------------
class _ToyState(NamedTuple):
    params: dict


class _ToyStream:
    def batch(self, i):
        return {"i": np.int64(i)}


def _toy_grad_fn(params, batch):
    i = int(batch["i"])
    g = np.zeros_like(params["w"])
    g[(i * 3) % 8] = 1.0 + (i % 4) * 0.25          # sparse + deterministic
    return float(i), {"w": g}


def _toy_apply_fn(state, grads):
    return _ToyState({"w": state.params["w"] - 0.1 * np.asarray(grads["w"])})


def test_trainer_uplink_rounds_end_to_end():
    server, sched = _server_with_project(quorum=1)
    state = _ToyState({"w": np.zeros(150_000, np.float32)})
    tr = VolunteerTrainer(grad_fn=_toy_grad_fn, apply_fn=_toy_apply_fn,
                          state=state, stream=_ToyStream(), micro_batches=2,
                          server=server, project="toy", uplink=True,
                          uplink_chunk_bytes=1 << 12)
    assert tr.sched is sched                       # one unit table
    tr.add_worker(SimWorker("v0"))
    tr.add_worker(SimWorker("v1"))
    hist = tr.run(3)

    # round 0 ships the base image; later rounds move only changed chunks
    assert hist[0].uplink_moved > 0
    for h in hist[1:]:
        assert 0 < h.uplink_moved < h.uplink_dense
        assert h.uplink_moved < hist[0].uplink_moved / 5
    # per-worker credit follows deduped bytes actually moved
    for wid in ("v0", "v1"):
        info = sched.workers[wid]
        assert info.uplink_bytes > 0
        assert info.credit > info.completed        # transfer credit on top
    # the server folded every unit and can reconstruct the canonical
    # gradient (bit-identical to the hash the quorum validated)
    proj = server.projects["toy"]
    assert sorted(proj.canonical_updates) == list(range(6))
    from repro.core.elastic import grad_hash
    uid = 5
    dec = server.resolve_round_update("toy", uid)
    arr = grad_compress.decompress_leaf(dec["['w']"], (150_000,), np.float32)
    assert grad_hash({"w": np.asarray(arr)}) == sched.units[uid].canonical
