import os

# smoke tests and benches must see ONE device; only launch/dryrun.py sets
# the 512-device flag (before any jax import) — never set it here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
