"""Property-based scheduler-invariant tests (hypothesis).

Under arbitrary interleavings of submit / request / report (honest or
corrupt) / leave / join / clock-advance / credit_transfer, the volunteer
scheduler must conserve its ledger:

* every submitted unit completes **exactly once** — the drain log never
  repeats a unit, and nothing is lost once a quorum of honest finishers
  works the backlog down;
* a unit never holds more than ``replication + max_extra_results``
  results (the replica-escalation cap);
* total minted credit equals completed units plus the MiB moved through
  ``credit_transfer`` — no interleaving mints or destroys credit.

Corrupt results use unique hashes and are capped per unit at
``replication + max_extra_results - quorum`` so a unit always retains
enough result slots for an honest quorum; without the cap an adversary
could legitimately exhaust a unit's slots (BOINC's max_error_results
marks such units as errors — this scheduler keeps them open forever,
which would be a different invariant).
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.scheduler import SimClock, VolunteerScheduler

SETTINGS = dict(max_examples=30, deadline=None)

OP = st.one_of(
    st.tuples(st.just("submit"), st.integers(1, 4)),
    st.tuples(st.just("join"), st.just(0)),
    st.tuples(st.just("request"), st.integers(0, 7)),
    st.tuples(st.just("report"), st.integers(0, 7), st.booleans()),
    st.tuples(st.just("leave"), st.integers(0, 7)),
    st.tuples(st.just("rejoin"), st.integers(0, 7)),
    st.tuples(st.just("advance"), st.integers(1, 240)),
    st.tuples(st.just("transfer"), st.integers(0, 7), st.integers(1, 8)),
)


def drive(ops, rep, quo):
    """Run one op sequence; assert every invariant along the way and
    after an honest drain."""
    clock = SimClock()
    s = VolunteerScheduler(replication=rep, quorum=quo, deadline_s=20.0,
                           backoff_base_s=0.5, backoff_max_s=8.0,
                           clock=clock)
    next_uid, next_wid, bad = 0, 0, 0
    alive, everyone = [], []
    outstanding = []                 # (worker, unit) leases granted to us
    corrupt_count = {}               # unit -> diverging results recorded
    corrupt_cap = rep + s.max_extra_results - quo
    transferred_mib = 0.0
    drained = []

    def spawn():
        nonlocal next_wid
        w = f"w{next_wid}"           # fresh ids here; the "rejoin" op
        next_wid += 1                # below reuses departed ids, and the
        s.join(w)                    # revive-in-place join keeps their
        alive.append(w)              # credit, so conservation holds
        everyone.append(w)           # across every leave -> rejoin cycle
        return w

    spawn()
    for op in ops:
        kind = op[0]
        if kind == "submit":
            for _ in range(op[1]):
                s.submit(next_uid, {"i": next_uid})
                corrupt_count[next_uid] = 0
                next_uid += 1
        elif kind == "join":
            spawn()
        elif kind == "request" and alive:
            w = alive[op[1] % len(alive)]
            wu = s.request_work(w)
            if wu is not None:
                outstanding.append((w, wu.unit_id))
        elif kind == "report" and outstanding:
            w, uid = outstanding.pop(op[1] % len(outstanding))
            if op[2] and corrupt_count[uid] < corrupt_cap:
                bad += 1
                corrupt_count[uid] += 1
                s.report(w, uid, f"bad-{bad}")
            else:
                s.report(w, uid, f"h{uid}")
        elif kind == "leave" and len(alive) > 1:
            w = alive.pop(op[1] % len(alive))
            s.leave(w)
        elif kind == "rejoin":
            departed = [w for w in everyone if w not in alive]
            if departed:
                w = departed[op[1] % len(departed)]
                info = s.join(w)     # revive in place: ledger survives
                assert info.alive
                alive.append(w)
        elif kind == "advance":
            clock.advance(op[1] / 2.0)
        elif kind == "transfer" and everyone:
            w = everyone[op[1] % len(everyone)]
            s.credit_transfer(w, op[2] << 18)     # op[2]/4 MiB
            transferred_mib += op[2] / 4.0
        drained.extend(s.drain_completed())
        for wu in s.units.values():               # escalation cap, always
            assert len(wu.results) <= wu.replication + wu.max_extra_results

    # work the backlog down with a quorum of honest finishers
    finishers = [spawn() for _ in range(quo)]
    for _ in range(4 * max(1, s.open_backlog()) * (quo + rep) + 40):
        if s.done():
            break
        for w in finishers:
            wu = s.request_work(w)
            if wu is not None:
                s.report(w, wu.unit_id, f"h{wu.unit_id}")
        clock.advance(40.0)     # clears back-off, expires stale leases
        drained.extend(s.drain_completed())
    assert s.done(), f"backlog never drained: {s.open_backlog()} open"

    drained.extend(s.drain_completed())
    done_ids = [uid for uid, _ in drained]
    assert len(done_ids) == len(set(done_ids))    # at most once
    assert set(done_ids) == set(range(next_uid))  # and nothing lost
    for wu in s.units.values():
        assert wu.completed
        assert len(wu.results) <= wu.replication + wu.max_extra_results
    total_credit = sum(i.credit for i in s.workers.values())
    assert total_credit == pytest.approx(next_uid + transferred_mib)
    return s


@settings(**SETTINGS)
@given(ops=st.lists(OP, max_size=150),
       repq=st.sampled_from([(1, 1), (2, 1), (2, 2), (3, 2), (3, 3)]))
def test_scheduler_conserves_its_ledger(ops, repq):
    drive(ops, *repq)


PLANE_OP = st.one_of(
    st.tuples(st.just("submit"), st.integers(1, 4)),
    st.tuples(st.just("join"), st.just(0)),
    st.tuples(st.just("request"), st.integers(0, 7)),
    st.tuples(st.just("report"), st.integers(0, 7)),
    st.tuples(st.just("leave"), st.integers(0, 7)),
    st.tuples(st.just("rejoin"), st.integers(0, 7)),
    st.tuples(st.just("advance"), st.integers(1, 240)),
    st.tuples(st.just("transfer"), st.integers(0, 7), st.integers(1, 8)),
    st.tuples(st.just("kill_shard"), st.integers(0, 5)),
    st.tuples(st.just("rejoin_shard"), st.just(0)),
    st.tuples(st.just("add_shard"), st.just(0)),
    st.tuples(st.just("split_shard"), st.integers(0, 5)),
)


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(PLANE_OP, max_size=120))
def test_plane_conserves_credit_across_membership_churn(ops):
    """Total minted credit stays completed-units + transferred MiB under
    arbitrary interleavings of volunteer leave -> rejoin with shard
    fail/rejoin/add/split — no handoff or ledger merge mints or loses."""
    from repro.core.shardplane import ShardedScheduler

    clock = SimClock()
    p = ShardedScheduler(shards=3, replication=1, quorum=1,
                         deadline_s=20.0, backoff_base_s=0.5,
                         backoff_max_s=8.0, watermark=2, refill_batch=4,
                         clock=clock)
    next_uid, next_wid = 0, 0
    alive, everyone, outstanding = [], [], []
    killed_shards = []
    transferred_mib = 0.0
    drained = []

    def spawn():
        nonlocal next_wid
        w = f"w{next_wid}"
        next_wid += 1
        p.join(w)
        alive.append(w)
        everyone.append(w)
        return w

    spawn()
    for op in ops:
        kind = op[0]
        if kind == "submit":
            for _ in range(op[1]):
                p.submit(next_uid, {"i": next_uid})
                next_uid += 1
        elif kind == "join":
            spawn()
        elif kind == "request" and alive:
            w = alive[op[1] % len(alive)]
            wu = p.request_work(w)
            if wu is not None:
                outstanding.append((w, wu.unit_id))
        elif kind == "report" and outstanding:
            w, uid = outstanding.pop(op[1] % len(outstanding))
            p.report(w, uid, f"h{uid}")
        elif kind == "leave" and len(alive) > 1:
            w = alive.pop(op[1] % len(alive))
            p.leave(w)
        elif kind == "rejoin":
            departed = [w for w in everyone if w not in alive]
            if departed:
                w = departed[op[1] % len(departed)]
                p.join(w)
                alive.append(w)
        elif kind == "advance":
            clock.advance(op[1] / 2.0)
        elif kind == "transfer" and everyone:
            w = everyone[op[1] % len(everyone)]
            p.credit_transfer(w, op[2] << 18)     # op[2]/4 MiB
            transferred_mib += op[2] / 4.0
        elif kind == "kill_shard":
            shards_up = p.alive_shards()
            if len(shards_up) > 1:
                victim = shards_up[op[1] % len(shards_up)]
                p.fail_shard(victim)
                killed_shards.append(victim)
        elif kind == "rejoin_shard" and killed_shards:
            p.rejoin_shard(killed_shards.pop(0))
        elif kind == "add_shard":
            if p.n_shards < 6:
                p.add_shard()
        elif kind == "split_shard":
            shards_up = p.alive_shards()
            cand = [i for i in shards_up
                    if sum(1 for o in p._range_owner if o == i) >= 2]
            if len(shards_up) > 1 and cand:
                p.split_shard(cand[op[1] % len(cand)])
        drained.extend(p.drain_completed())

    # work the backlog down with fresh honest finishers
    finishers = [spawn() for _ in range(2)]
    for _ in range(8 * max(1, p.open_backlog()) + 60):
        if p.done():
            break
        for w in finishers:
            wu = p.request_work(w)
            if wu is not None:
                p.report(w, wu.unit_id, f"h{wu.unit_id}")
        clock.advance(40.0)
        drained.extend(p.drain_completed())
    assert p.done(), f"backlog never drained: {p.open_backlog()} open"
    drained.extend(p.drain_completed())

    done_ids = [uid for uid, _ in drained]
    assert len(done_ids) == len(set(done_ids))
    assert set(done_ids) == set(range(next_uid))
    total_credit = sum(i.credit for i in p.workers.values())
    assert total_credit == pytest.approx(next_uid + transferred_mib), \
        "membership churn minted or destroyed credit"


@settings(**SETTINGS)
@given(ops=st.lists(OP, max_size=80))
def test_forged_reports_never_complete_or_mint(ops):
    """Interleave every op with a forged report from a worker that never
    held a lease: completions, results and credit must be exactly what
    the honest run produces — plus one rejection counted per forgery."""
    clock = SimClock()
    s = VolunteerScheduler(replication=2, quorum=2, deadline_s=20.0,
                           clock=clock)
    s.join("a")
    s.join("b")
    forged = 0
    next_uid = 0
    outstanding = []
    for op in ops:
        kind = op[0]
        if kind == "submit":
            for _ in range(op[1]):
                s.submit(next_uid, {})
                next_uid += 1
        elif kind == "request":
            w = ("a", "b")[op[1] % 2]
            wu = s.request_work(w)
            if wu is not None:
                outstanding.append((w, wu.unit_id))
        elif kind == "report" and outstanding:
            w, uid = outstanding.pop(op[1] % len(outstanding))
            s.report(w, uid, f"h{uid}")
        elif kind == "advance":
            clock.advance(op[1] / 2.0)
        # the attack: a free-rider reports on every open unit it can see
        for wu in list(s.units.values()):
            if not wu.completed:
                assert not s.report("freerider", wu.unit_id, f"h{wu.unit_id}")
                forged += 1
    assert s.stats["unsolicited_results"] == forged
    assert s.workers.get("freerider") is None or \
        s.workers["freerider"].credit == 0.0
    for wu in s.units.values():
        assert "freerider" not in wu.results
    total_credit = sum(i.credit for i in s.workers.values())
    assert total_credit == pytest.approx(s.stats["completed"])
