"""Continuous-batching engine: outputs must equal isolated single-request
generation (greedy decode is deterministic), across mixed prompt lengths
and slot churn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, reduced
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.serving.engine import Request, ServingEngine

RUN = RunConfig(remat="none", block_kv=16, ssm_chunk=8,
                compute_dtype=jnp.float32)


def _single_reference(cfg, params, prompt, n_new, max_len):
    """Slot-free greedy generation for one request."""
    prefill = api.make_prefill_step(cfg, max_len, RUN)
    decode = api.make_decode_step(cfg, RUN)
    logits, caches = prefill(params, {"tokens": prompt[None, :]})
    out = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, caches = decode(params, caches,
                            {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                             "index": jnp.int32(pos)})
        out.append(int(jnp.argmax(lg[0, 0, :cfg.vocab_size])))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["granite-3-2b", "hymba-1.5b"])
def test_engine_matches_isolated_generation(arch):
    cfg = reduced(get_arch(arch))
    params = init_tree(api.param_specs(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    MAX = 64
    reqs, refs = [], []
    for i, (plen, gen) in enumerate([(8, 6), (12, 4), (5, 8), (9, 5), (7, 3)]):
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(Request(i, prompt, gen))
        refs.append(_single_reference(cfg, params, prompt, gen, MAX))

    engine = ServingEngine(cfg, params, slots=2, max_len=MAX, run=RUN)
    done = engine.run_queue(reqs)
    assert len(done) == 5
    assert engine.stats["served"] == 5
    by_id = {r.request_id: r for r in done}
    for i, ref in enumerate(refs):
        assert by_id[i].output == ref, (i, by_id[i].output, ref)
    # continuous batching actually shared decode steps across slots
    total_tokens = sum(len(r.output) for r in done)
    assert engine.stats["decode_steps"] < total_tokens


def test_engine_latency_accounting():
    cfg = reduced(get_arch("granite-3-2b"))
    params = init_tree(api.param_specs(cfg), jax.random.key(1))
    rng = np.random.default_rng(1)
    req = Request(0, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)
    engine = ServingEngine(cfg, params, slots=1, max_len=32, run=RUN)
    done = engine.run_queue([req])[0]
    assert done.first_token_s is not None and done.done_s >= done.first_token_s
    assert len(done.output) == 3
