"""V-BOINC core unit tests: chunk store, snapshots, DepDisks, control plane,
scheduler, server."""
import numpy as np
import pytest

from repro.core.capsule import CapsuleSpec, boot
from repro.core.chunkstore import ChunkStore
from repro.core.control import (CapsuleRuntime, Coordinator, HostSupervisor,
                                JobState, RuntimeState)
from repro.core.depdisk import DiskSet
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.server import Project, VBoincServer
from repro.core.snapshots import SnapshotManager
from repro.models.lm import RunConfig


# ---------------------------------------------------------------------------
# chunk store
# ---------------------------------------------------------------------------
def test_chunkstore_dedup_and_integrity(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=1 << 12)
    data = np.arange(10_000, dtype=np.float32).tobytes()
    h1 = store.put_buffer(memoryview(bytearray(data)))
    before = store.stats["put_bytes"]
    h2 = store.put_buffer(memoryview(bytearray(data)))
    assert h1 == h2
    assert store.stats["put_bytes"] == before        # full dedup
    assert store.get_buffer(h1) == data
    # tamper detection
    victim = h1[0]
    p = store._path(victim)
    p.write_bytes(b"tampered")
    with pytest.raises(IOError):
        store.get(victim)


def test_chunkstore_gc(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=64)
    keep = store.put(b"a" * 64)
    drop = store.put(b"b" * 64)
    removed = store.gc({keep})
    assert removed == 1
    assert store.has(keep) and not store.has(drop)


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------
def _state(x=0.0):
    return {"w": np.full((1000,), 1.0 + x, np.float32),
            "frozen": np.arange(4096, dtype=np.float32),
            "step": np.int32(x)}


def test_snapshot_restore_roundtrip():
    mgr = SnapshotManager(ChunkStore(chunk_bytes=1 << 12))
    info = mgr.snapshot(_state(1), step=1, aux={"cursor": {"next_index": 7}})
    assert info.kind == "base"
    got, aux = mgr.restore(target_tree=_state(0))
    assert aux["cursor"]["next_index"] == 7
    np.testing.assert_array_equal(got["w"], _state(1)["w"])
    assert got["step"] == 1


def test_differencing_snapshots_store_only_changes():
    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store, keep_last=10)
    mgr.snapshot(_state(1), step=1)
    info2 = mgr.snapshot(_state(1), step=2)      # identical state
    assert info2.kind == "diff"
    assert info2.new_bytes == 0                  # pure dedup
    info3 = mgr.snapshot(_state(2), step=3)      # w+step changed, frozen not
    assert 0 < info3.new_bytes < info3.total_bytes
    assert info3.dedup_bytes > 0                 # frozen tensor reused


def test_snapshot_gc_respects_keep_last():
    store = ChunkStore(chunk_bytes=1 << 12)
    mgr = SnapshotManager(store, keep_last=2)
    for i in range(5):
        mgr.snapshot(_state(i), step=i)
    assert len(mgr.order) == 2
    # all remaining manifests restorable after the sweep
    for sid in mgr.order:
        got, _ = mgr.restore(sid, target_tree=_state(0))
        assert got["w"].shape == (1000,)


def test_async_snapshot_overlaps():
    mgr = SnapshotManager(ChunkStore(chunk_bytes=1 << 12), async_mode=True)
    fut = mgr.snapshot(_state(1), step=1, block=False)
    info = mgr.wait()
    assert info.total_bytes > 0
    got, _ = mgr.restore(target_tree=_state(0))
    np.testing.assert_array_equal(got["w"], _state(1)["w"])


# ---------------------------------------------------------------------------
# DepDisks
# ---------------------------------------------------------------------------
def test_depdisk_partitioning_and_swap():
    store = ChunkStore(chunk_bytes=1 << 12)
    disks = DiskSet(store, keep_last=2)
    base = {"params": np.ones(5000, np.float32)}
    disks.create_base(base)
    disks.attach_dep("taskA", {"opt": np.zeros(2000, np.float32)})
    infoA = disks.snapshot_disk("taskA", {"opt": np.ones(2000, np.float32)},
                                step=1)
    assert infoA.new_bytes > 0
    # base untouched by task writes
    infoB = disks.snapshot_disk("base", base, step=1)
    assert infoB.new_bytes == 0
    # swap project: detach A, attach B; base stays
    disks.swap_task("taskA", "taskB", {"opt": np.full(2000, 2.0, np.float32)})
    names = {d.name: d for d in disks.disks()}
    assert not names["taskA"].attached and names["taskB"].attached
    assert names["base"].attached
    # re-attach A later and restore its state
    disks._attached["taskA"] = True
    got, _ = disks.restore_disk("taskA",
                                target_tree={"opt": np.zeros(2000,
                                                             np.float32)})
    np.testing.assert_array_equal(got["opt"], np.ones(2000, np.float32))


def test_depdisk_detached_rejects_snapshot():
    disks = DiskSet(ChunkStore())
    disks.attach_dep("t")
    disks.detach("t")
    with pytest.raises(KeyError):
        disks.snapshot_disk("t", {"x": np.zeros(4)}, step=0)


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------
def test_two_level_command_wrapping():
    rt = CapsuleRuntime("r0")
    sup = HostSupervisor("h0", rt)
    # guestcontrol requires a running VM (paper Fig. 2 semantics)
    assert not sup.boinccmd("suspend")["ok"]
    sup.control_vm("startvm")
    assert rt.state is RuntimeState.RUNNING
    assert sup.boinccmd("suspend")["ok"]
    assert rt.job_state is JobState.SUSPENDED
    assert not rt.accepting_work
    sup.boinccmd("resume")
    assert rt.accepting_work
    # vm-level pause != job-level suspend (controlvm vs boinccmd)
    sup.control_vm("pause")
    assert rt.state is RuntimeState.SUSPENDED
    assert rt.job_state is JobState.RUNNING
    assert not rt.accepting_work
    sup.control_vm("unpause")
    assert rt.accepting_work
    # verb namespaces are enforced
    assert not sup.boinccmd("poweroff")["ok"]
    assert not sup.control_vm("suspend")["ok"]


def test_coordinator_failure_detection():
    coord = Coordinator()
    rts = []
    for i in range(3):
        rt = CapsuleRuntime(f"r{i}")
        sup = HostSupervisor(f"h{i}", rt, heartbeat_timeout=10.0)
        sup.control_vm("startvm")
        coord.register(sup)
        rts.append(rt)
    assert coord.failed_hosts() == []
    rts[1].last_heartbeat -= 100.0          # silent host
    assert coord.failed_hosts() == ["h1"]
    out = coord.broadcast("guest", "nomorework")
    assert all(v["ok"] for v in out.values())


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_quorum_rejects_minority_corruption():
    clock = SimClock()
    s = VolunteerScheduler(replication=3, quorum=2, clock=clock)
    s.submit(0, {})
    for w in ("a", "b", "c"):
        s.join(w)
        assert s.request_work(w) is not None
    s.report("a", 0, "GOOD")
    s.report("b", 0, "BAD")
    assert not s.units[0].completed
    s.report("c", 0, "GOOD")
    assert s.units[0].completed and s.units[0].canonical == "GOOD"
    assert s.workers["b"].invalid == 1
    assert s.workers["a"].credit > 0 and s.workers["b"].credit == 0


def test_lease_expiry_reissues():
    clock = SimClock()
    s = VolunteerScheduler(deadline_s=10.0, clock=clock)
    s.submit(0, {})
    s.join("w1")
    s.join("w2")
    assert s.request_work("w1").unit_id == 0
    assert s.request_work("w2") is None          # already leased
    clock.advance(11.0)
    assert s.request_work("w2").unit_id == 0     # re-issued after deadline
    assert s.stats["reissued"] == 1


def test_exponential_backoff():
    clock = SimClock()
    s = VolunteerScheduler(backoff_base_s=1.0, backoff_max_s=64.0,
                           clock=clock)
    s.join("w")
    assert s.request_work("w") is None           # no work at all
    t1 = s.workers["w"].backoff_until
    assert s.request_work("w") is None           # still backing off
    clock.advance(t1 + 1)
    s.request_work("w")
    t2 = s.workers["w"].backoff_until - clock()
    assert t2 > 1.0                               # grew exponentially


def test_straggler_duplicate_dispatch():
    clock = SimClock()
    s = VolunteerScheduler(deadline_s=10.0, straggler_factor=0.5,
                           clock=clock)
    s.submit(0, {})
    s.join("slow")
    s.join("fast")
    assert s.request_work("slow") is not None
    clock.advance(6.0)                            # > 0.5 * deadline
    dup = s.request_work("fast")
    assert dup is not None and dup.unit_id == 0
    assert s.stats["duplicates"] == 1
    s.report("fast", 0, "H")                      # first valid result wins
    assert s.units[0].completed


def test_worker_leave_drops_leases():
    clock = SimClock()
    s = VolunteerScheduler(clock=clock)
    s.submit(0, {})
    s.join("w")
    s.request_work("w")
    s.leave("w")
    s.join("w2")
    assert s.request_work("w2").unit_id == 0      # immediately available


def test_submit_explicit_overrides_are_honored():
    # regression: `replication or self.replication` silently replaced any
    # falsy explicit value with the scheduler default — submit(quorum=0)
    # became quorum=3 and the misconfiguration never surfaced
    s = VolunteerScheduler(replication=3, quorum=2, clock=SimClock())
    wu = s.submit(0, {}, replication=1, quorum=1)
    assert wu.replication == 1 and wu.quorum == 1
    s.join("w")
    s.request_work("w")
    assert s.report("w", 0, "H")                  # one result completes it
    with pytest.raises(ValueError):
        s.submit(1, {}, replication=0)
    with pytest.raises(ValueError):
        s.submit(1, {}, quorum=0)
    with pytest.raises(ValueError):
        s.submit(1, {}, replication=1, quorum=2)  # quorum > replication


def test_unsolicited_report_rejected():
    clock = SimClock()
    s = VolunteerScheduler(replication=2, quorum=2, clock=clock)
    s.submit(0, {})
    for w in ("a", "b"):
        s.join(w)
        assert s.request_work(w) is not None
    s.join("forger")                              # never held a lease
    assert not s.report("forger", 0, "EVIL")
    assert s.stats["unsolicited_results"] == 1
    assert "forger" not in s.units[0].results     # can't poison quorum
    s.report("a", 0, "GOOD")
    assert s.report("b", 0, "GOOD")
    assert s.units[0].canonical == "GOOD"
    assert s.workers["forger"].credit == 0.0


def test_straggler_duplicate_once_per_lease_lifetime():
    clock = SimClock()
    s = VolunteerScheduler(deadline_s=10.0, straggler_factor=0.5,
                           clock=clock)
    s.submit(0, {})
    for w in ("slow", "fast", "w3", "w4", "w5"):
        s.join(w)
    assert s.request_work("slow") is not None
    clock.advance(6.0)                            # > 0.5 * deadline
    assert s.request_work("fast").unit_id == 0    # the one duplicate
    assert s.stats["duplicates"] == 1
    # same lease lifetime: no further fan-out to other volunteers
    assert s.request_work("w3") is None
    clock.advance(11.0)                           # both leases expire
    assert s.request_work("w4").unit_id == 0      # fresh lease lifetime
    clock.advance(6.0)
    assert s.request_work("w5").unit_id == 0      # straggler re-armed
    assert s.stats["duplicates"] == 2


def test_backoff_resets_only_on_successful_dispatch():
    clock = SimClock()
    s = VolunteerScheduler(backoff_base_s=1.0, backoff_max_s=64.0,
                           clock=clock)
    s.join("w")
    assert s.request_work("w") is None            # no work -> k = 1
    assert s.workers["w"].backoff_k == 1
    assert s.request_work("w") is None            # rejected inside window:
    assert s.workers["w"].backoff_k == 1          # k must NOT move
    clock.advance(100.0)
    assert s.request_work("w") is None            # still no work -> k = 2
    assert s.workers["w"].backoff_k == 2
    s.submit(0, {})
    clock.advance(100.0)
    assert s.request_work("w") is not None        # success resets fully
    assert s.workers["w"].backoff_k == 0
    assert s.workers["w"].backoff_until == 0.0


def test_lease_expiry_across_clock_jump():
    # one large SimClock jump must expire every due lease in a single
    # call (heap pops), not just the first one found by a scan
    clock = SimClock()
    s = VolunteerScheduler(deadline_s=10.0, clock=clock)
    for uid in range(3):
        s.submit(uid, {})
    for i, w in enumerate(("a", "b", "c")):
        s.join(w)
        assert s.request_work(w) is not None
        clock.advance(2.0)                        # staggered deadlines
    clock.advance(50.0)                           # jump past all of them
    s.join("fresh")
    got = s.request_work("fresh")
    assert got is not None
    assert s.stats["reissued"] == 3
    for uid in range(3):
        assert list(s.units[uid].leases) in ([], ["fresh"])


# ---------------------------------------------------------------------------
# server + capsule
# ---------------------------------------------------------------------------
def test_capsule_manifest_integrity():
    spec = CapsuleSpec("granite-3-2b", "train_4k", RunConfig())
    same = CapsuleSpec("granite-3-2b", "train_4k", RunConfig())
    other = CapsuleSpec("granite-3-2b", "train_4k", RunConfig(remat="none"))
    assert spec.manifest_hash == same.manifest_hash
    assert spec.manifest_hash != other.manifest_hash
    with pytest.raises(PermissionError):
        boot(spec, mesh=None, verify_hash=other.manifest_hash)


def test_server_flow_probe_fetch_work():
    store = ChunkStore()
    server = VBoincServer(store)
    spec = CapsuleSpec("qwen2-1.5b", "train_4k", RunConfig())
    proj = Project("lm", spec, dep_manifest={"disk": "adamw-state"})
    proj.scheduler = VolunteerScheduler(clock=SimClock())
    server.publish(proj)
    key = server.register_user("vol")
    assert server.probe_dependencies("lm") == {"disk": "adamw-state"}
    got, missing, moved = server.fetch_capsule("lm", set(), key)
    assert got.manifest_hash == spec.manifest_hash and moved > 0
    # second fetch: chunks cached client-side -> nothing moves
    _, missing2, moved2 = server.fetch_capsule(
        "lm", {spec.manifest_hash}, key)
    assert moved2 == 0 and not missing2
    with pytest.raises(PermissionError):
        server.fetch_capsule("lm", set(), "bad-key")
    proj.scheduler.submit(0, {"batch_index": 0})
    unit = server.request_work("lm", "vol")
    assert unit is not None
    assert server.report_result("lm", "vol", unit.unit_id, "H")
