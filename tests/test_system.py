"""End-to-end behaviour tests for the paper's system: full volunteer
training rounds with failures, quorum validation, differencing snapshots
and bit-exact crash recovery (the V-BOINC guarantees, on real jax compute).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch, reduced
from repro.core.chunkstore import ChunkStore
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.snapshots import SnapshotManager
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw

RUN = RunConfig(remat="none", block_kv=8, ssm_chunk=8)
OC = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=500)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("granite-3-2b"))
    specs = api.state_specs(cfg)
    loss_fn = api.make_eval_loss(cfg, RUN)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def apply_fn(state, grads):
        p, o, _ = adamw.update(OC, grads, state.opt, state.params)
        return api.TrainState(p, o)

    stream = TokenStream(DataConfig(cfg.vocab_size, 32, 4, seed=0))
    return cfg, specs, grad_fn, apply_fn, stream


def _trainer(setup, seed=0, snap=None, sched=None, micro=4):
    cfg, specs, grad_fn, apply_fn, stream = setup
    state = api.TrainState(init_tree(specs.params, jax.random.key(0)),
                           init_tree(specs.opt, jax.random.key(0)))
    return VolunteerTrainer(grad_fn=grad_fn, apply_fn=apply_fn, state=state,
                            stream=stream, micro_batches=micro,
                            scheduler=sched, snapshots=snap,
                            snapshot_every=2, seed=seed)


def test_reference_training_learns(setup):
    tr = _trainer(setup)
    for i in range(3):
        tr.add_worker(SimWorker(f"w{i}"))
    hist = tr.run(8)
    assert hist[-1].loss < hist[0].loss - 0.05
    assert all(h.invalid == 0 for h in hist)


def test_faulty_fleet_matches_reference_bitwise(setup):
    ref = _trainer(setup)
    for i in range(3):
        ref.add_worker(SimWorker(f"w{i}"))
    ref_hist = ref.run(5)

    sched = VolunteerScheduler(replication=2, quorum=2, deadline_s=5.0,
                               clock=SimClock())
    tr = _trainer(setup, seed=1, sched=sched)
    tr.add_worker(SimWorker("good0"))
    tr.add_worker(SimWorker("good1"))
    tr.add_worker(SimWorker("liar", corrupt_prob=0.3,
                            rng=np.random.default_rng(7)))
    tr.add_worker(SimWorker("flaky", fail_prob=0.25,
                            rng=np.random.default_rng(8)))
    hist = tr.run(5)
    for a, b in zip(ref_hist, hist):
        assert abs(a.loss - b.loss) < 1e-6     # deterministic replay


def test_crash_restore_is_bit_exact(setup):
    cfg, specs, grad_fn, apply_fn, stream = setup
    store = ChunkStore(chunk_bytes=1 << 14)
    snap = SnapshotManager(store, keep_last=2)
    ref = _trainer(setup)
    for i in range(2):
        ref.add_worker(SimWorker(f"w{i}"))
    ref_hist = ref.run(6)

    tr = _trainer(setup, snap=snap)
    for i in range(2):
        tr.add_worker(SimWorker(f"w{i}"))
    tr.run(4)                                    # snapshots at steps 1,3
    # "host terminates"; a new trainer restores the latest snapshot
    abstract = jax.eval_shape(
        lambda: api.TrainState(init_tree(specs.params, jax.random.key(0)),
                               init_tree(specs.opt, jax.random.key(0))))
    tr2 = _trainer(setup, seed=9)
    tr2.snapshots = snap
    next_step = tr2.restore_latest(abstract)
    assert next_step == 4
    for i in range(2):
        tr2.add_worker(SimWorker(f"n{i}"))
    cont = tr2.run(2, start_step=next_step)
    for a, b in zip(ref_hist[next_step:], cont):
        assert abs(a.loss - b.loss) < 1e-6


def test_differencing_snapshots_dedup(setup):
    store = ChunkStore(chunk_bytes=1 << 12)
    snap = SnapshotManager(store, keep_last=3)
    tr = _trainer(setup, snap=snap)
    tr.add_worker(SimWorker("w0"))
    tr.snapshot_every = 1
    tr.run(3)
    assert any(m.kind == "base" for m in snap.manifests.values())
    assert any(m.kind == "diff" for m in snap.manifests.values())
    # opt.step & friends change but frozen-ish chunks dedup across snapshots
    assert store.stats["dedup_chunks"] >= 0
    # latest restore works
    got, aux = snap.restore(target_tree=None)
    assert "cursor" in aux


def test_elastic_respawn_keeps_training(setup):
    tr = _trainer(setup, seed=3)
    tr.add_worker(SimWorker("mortal", fail_prob=0.9,
                            rng=np.random.default_rng(1)))
    spawned = []

    def respawn(trainer):
        wid = f"fresh{len(spawned)}"
        spawned.append(wid)
        trainer.add_worker(SimWorker(wid))

    tr.respawn = respawn
    hist = tr.run(2)
    assert len(hist) == 2 and len(spawned) >= 1
