"""Edge capsule distribution: discovery, caches, churn, Wire shims.

Covers the PR-9 acceptance surface:

* discovery ranking (coverage / load / RTT / preferred) and its churn
  behaviour — a killed cache drops out, a stale revive demand-fills
  before serving, same-seed runs pick byte-identical routes;
* LRU-by-closure eviction (whole closures, never a torn chain);
* routing through ``VBoincServer.fetch_capsule`` and
  ``VolunteerTrainer.restore_latest`` with byte-identical accounting;
* the shared ``Membership`` mixin driving both planes;
* the deprecated ``transfer_plan``/``ingest_plan``/``export_records``/
  ``ingest`` shims: they warn and delegate to the Wire verbs.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import telemetry as tlm
from repro.core.chunkstore import ChunkStore, TransferPlan, Wire
from repro.core.edge import (EdgeCache, EdgeTier, FetchResult, closure_key,
                             simulated_rtt_ms)
from repro.core.elastic import Cursor, VolunteerTrainer
from repro.core.replica import ReplicaSet
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.sim import ChurnSim
from repro.core.snapshots import SnapshotManager

CHUNK = 1 << 12


def _capsule(chunks: int = 6, seed: int = 0):
    """Origin store holding raw base chunks + a short delta chain."""
    rng = np.random.default_rng(seed)
    store = ChunkStore(chunk_bytes=CHUNK)
    base = rng.integers(0, 256, size=chunks * CHUNK, dtype=np.uint8)
    refs = store.put_buffer(memoryview(base))
    xor = np.zeros(CHUNK, np.uint8)
    xor[3] = 7
    refs[0] = store.put_delta(refs[0], xor.tobytes())
    return store, refs


def _tier(caches: int = 3, *, prefetch: bool = True, chunks: int = 6,
          scheduler=None, telemetry=None):
    origin, refs = _capsule(chunks)
    tier = EdgeTier(origin, [EdgeCache(f"edge-{i}") for i in range(caches)],
                    scheduler=scheduler, telemetry=telemetry)
    if prefetch:
        tier.prefetch(refs, base_only=False)
    return origin, refs, tier


# ---------------------------------------------------------------------------
# discovery ranking
# ---------------------------------------------------------------------------
def test_discover_ranks_by_coverage_then_load_then_rtt():
    origin, refs, tier = _tier(3)
    plan = origin.plan_send(refs, set())
    ranked = [i for i, _ in tier.discover(plan.refs)]
    # all full coverage + zero load: RTT (then preferred/index) decides,
    # and the order is a pure function of the cache ids
    rtts = [tier.members[i].rtt_ms for i in ranked]
    assert rtts == sorted(rtts)
    # serving bumps load: the busy cache falls behind an idle equal peer
    first = ranked[0]
    tier.members[first].serve(plan.refs)
    tier.members[first].serve(plan.refs)
    assert tier.discover(plan.refs)[0][0] != first


def test_discover_prefers_coverage_over_everything():
    origin, refs, tier = _tier(2, prefetch=False)
    plan = origin.plan_send(refs, set())
    tier.members[1].fill_from(origin, plan.refs)   # only cache 1 is warm
    assert tier.discover(plan.refs)[0][0] == 1


def test_killed_cache_drops_out_of_rankings():
    origin, refs, tier = _tier(3)
    plan = origin.plan_send(refs, set())
    sim = ChurnSim(seed=1, edges=tier)
    killed = sim.random_cache_kill()
    assert killed is not None
    assert killed not in [i for i, _ in tier.discover(plan.refs)]
    sim.revive_cache(killed)
    assert killed in [i for i, _ in tier.discover(plan.refs)]


def test_stale_revive_demand_fills_before_serving():
    origin, refs, tier = _tier(2)
    plan = origin.plan_send(refs, set())
    sim = ChurnSim(seed=3, edges=tier)
    sim.kill_cache(0)
    sim.revive_cache(0, stale=True)       # back, but empty
    assert not tier.members[0].can_serve(plan.refs)
    sim.kill_cache(1)                     # isolate the stale cache
    fills = tier.stats["fills"]
    res = tier.fetch(refs, set())
    assert res.route == "edge-0"
    assert tier.stats["fills"] == fills + 1       # filled, then served
    assert tier.members[0].can_serve(plan.refs)
    # warm now: the next fetch is a hit, no further origin egress
    egress = tier.stats["origin_egress_bytes"]
    tier.fetch(refs, set())
    assert tier.stats["origin_egress_bytes"] == egress


def _route_script(seed: int) -> list[str]:
    origin, refs, tier = _tier(3)
    sim = ChurnSim(seed=seed, edges=tier)
    routes = [tier.fetch(refs, set()).route]
    killed = sim.random_cache_kill()
    routes.append(tier.fetch(refs, set()).route)
    sim.revive_cache(killed, stale=True)
    for i in tier.alive_indices():
        if i != killed:
            sim.kill_cache(i)
    routes.append(tier.fetch(refs, set()).route)
    return routes


@pytest.mark.parametrize("seed", [7, 19, 42])
def test_same_seed_runs_pick_byte_identical_routes(seed):
    assert _route_script(seed) == _route_script(seed)


# ---------------------------------------------------------------------------
# fetch routing + accounting
# ---------------------------------------------------------------------------
def test_fetch_is_byte_identical_and_dedup_aware():
    origin, refs, tier = _tier(2)
    client = ChunkStore(chunk_bytes=CHUNK)
    res = tier.fetch(refs, set(), client_store=client)
    assert res.route.startswith("edge-")
    assert client.resolve_buffer(refs) == origin.resolve_buffer(refs)
    # identical plan accounting to the no-edge path
    plan = origin.plan_send(refs, set())
    assert (res.missing, res.bytes_moved, res.bytes_dedup) == tuple(plan)
    # a client already holding everything needs nothing: dedup short-cut
    res2 = tier.fetch(refs, set(client.all_refs()))
    assert res2.route == "dedup" and res2.bytes_moved == 0


def test_fetch_falls_back_to_origin_when_no_cache_alive():
    origin, refs, tier = _tier(2)
    sim = ChurnSim(seed=0, edges=tier)
    sim.kill_cache(0)
    sim.kill_cache(1)
    res = tier.fetch(refs, set())
    assert res.route == "origin"
    assert tier.stats["origin_egress_bytes"] >= res.bytes_moved


def test_fetch_earns_credit_for_the_serving_cache():
    sched = VolunteerScheduler()
    origin, refs, tier = _tier(2, scheduler=sched)
    res = tier.fetch(refs, set())
    info = sched.workers[res.route]
    assert info.uplink_bytes == res.bytes_moved
    assert info.credit > 0


def test_cold_demand_fill_mints_no_transfer_credit():
    """S4 regression: on a demand-fill miss the origin moved the bytes
    (its egress meter ran) — the cache must not also be credited for
    them.  Credit settles only on bytes served from residency."""
    sched = VolunteerScheduler()
    origin, refs, tier = _tier(2, prefetch=False, scheduler=sched)
    res = tier.fetch(refs, set())
    assert res.route != "origin"             # a cache served, via a fill
    assert tier.stats["fills"] == 1
    assert tier.stats["fill_bytes"] == res.bytes_moved
    total = sum(i.credit for i in sched.workers.values())
    assert total == 0, "cache credited for bytes the origin moved"
    # the SAME fetch again is now fully resident: full credit this time
    res2 = tier.fetch(refs, set())
    assert tier.stats["fills"] == 1          # no second fill
    info = sched.workers[res2.route]
    assert info.uplink_bytes == res2.bytes_moved
    assert info.credit > 0


def test_fetch_route_trace_events():
    tel = tlm.Telemetry(tracing=True, clock=SimClock())
    origin, refs, tier = _tier(2, telemetry=tel)
    res = tier.fetch(refs, set())
    ev = [e for e in tel.events if e.get("kind") == "fetch_route"]
    assert ev and ev[-1]["route"] == res.route
    assert ev[-1]["bytes"] == res.bytes_moved


def test_fetch_result_unpacks_like_legacy_tuple():
    res = FetchResult(["a"], 10, 3, "origin")
    missing, moved, dedup = res
    assert (missing, moved, dedup) == (["a"], 10, 3)
    assert len(res) == 3 and res[1] == 10


# ---------------------------------------------------------------------------
# cache internals: LRU by closure, prefetch
# ---------------------------------------------------------------------------
def test_lru_evicts_whole_closures_never_tearing_chains():
    origin = ChunkStore(chunk_bytes=CHUNK)
    closures = []
    rng = np.random.default_rng(9)
    for i in range(3):
        data = rng.integers(0, 256, size=2 * CHUNK, dtype=np.uint8)
        refs = origin.put_buffer(memoryview(data))
        xor = np.zeros(CHUNK, np.uint8)
        xor[i] = 1
        refs[0] = origin.put_delta(refs[0], xor.tobytes())
        closures.append(refs)
    nbytes = sum(origin.object_size(r)
                 for r in origin.live_closure(closures[0]))
    cache = EdgeCache("tiny", capacity_bytes=int(nbytes * 2.5))
    for refs in closures:
        cache.fill_from(origin, refs)
    # capacity fits ~2 closures: the oldest was evicted whole
    assert not any(cache.store.has(r) for r in closures[0])
    for refs in closures[1:]:
        assert cache.can_serve(origin.live_closure(refs))
        # a served chain must still resolve — no torn deltas
        assert (cache.store.resolve_buffer(refs)
                == origin.resolve_buffer(refs))


def test_serve_touches_every_intersecting_closure():
    """S3 regression: a subset fetch must refresh the recency of the
    resident closure(s) it hits, or hot closures evict as if cold."""
    origin = ChunkStore(chunk_bytes=CHUNK)
    closures = []
    rng = np.random.default_rng(11)
    for i in range(3):
        data = rng.integers(0, 256, size=2 * CHUNK, dtype=np.uint8)
        refs = origin.put_buffer(memoryview(data))
        xor = np.zeros(CHUNK, np.uint8)
        xor[i] = 1
        refs[0] = origin.put_delta(refs[0], xor.tobytes())
        closures.append(refs)
    a, b, c = closures
    nbytes = sum(origin.object_size(r) for r in origin.live_closure(a))
    cache = EdgeCache("tiny", capacity_bytes=int(nbytes * 2.5))
    cache.fill_from(origin, a)
    cache.fill_from(origin, b)
    # a *subset* fetch of A's closure (one raw chunk, not the admitted
    # key) — the touch must still land on A's resident closure
    cache.serve([a[1]])
    cache.fill_from(origin, c)               # capacity: one closure evicts
    # LRU order after the touch is B < A < C, so B left and A survived
    assert cache.can_serve(origin.live_closure(a))
    assert not any(cache.store.has(r) for r in b)


def test_prefetch_base_only_skips_delta_chains():
    origin, refs, tier = _tier(2, prefetch=False)
    moved = tier.prefetch(refs, base_only=True)
    assert moved > 0
    cache = tier.members[0]
    raw = [r for r in refs[1:]]               # refs[0] is the delta head
    assert all(cache.store.has(r) for r in raw)
    assert not cache.store.has(refs[0])
    assert tier.stats["prefetch_bytes"] == moved


def test_closure_key_and_rtt_are_stable():
    assert closure_key(["b", "a"]) == closure_key(["a", "b", "a"])
    assert simulated_rtt_ms("edge-0") == simulated_rtt_ms("edge-0")
    assert 5 <= simulated_rtt_ms("anything") < 55


# ---------------------------------------------------------------------------
# server + trainer routing
# ---------------------------------------------------------------------------
def _published_server(store, edge=None):
    from repro.core.capsule import CapsuleSpec
    from repro.core.server import Project, VBoincServer
    from repro.models.lm import RunConfig

    server = VBoincServer(store, edge=edge)
    spec = CapsuleSpec("granite-3-2b", "train_4k", RunConfig())
    server.publish(Project("p", spec))
    key = server.register_user("vol")
    return server, key


def test_server_fetch_capsule_routes_through_edge():
    store = ChunkStore(chunk_bytes=CHUNK)
    plain, key = _published_server(store)
    spec0, missing0, moved0 = plain.fetch_capsule("p", set(), key)

    edge = EdgeTier(store, [EdgeCache("edge-0"), EdgeCache("edge-1")])
    edged, key = _published_server(store, edge=edge)
    spec, missing, moved = edged.fetch_capsule("p", set(), key)
    # identical plan accounting, different egress meter
    assert (missing, moved) == (missing0, moved0)
    log = edged.transfers["p"]
    assert sum(log.routes.values()) == 1
    (route,) = log.routes
    assert route.startswith("edge-")


def test_server_rejects_foreign_edge_tier():
    from repro.core.server import VBoincServer
    tier = EdgeTier(ChunkStore(), [EdgeCache("x")])
    with pytest.raises(ValueError):
        VBoincServer(ChunkStore(), edge=tier)


def test_trainer_restore_latest_routes_through_edge():
    store = ChunkStore(chunk_bytes=CHUNK)
    mgr = SnapshotManager(store, keep_last=10)
    rng = np.random.default_rng(4)
    x = rng.standard_normal(20_000).astype(np.float32)
    early_refs: set = set()
    for i in range(3):
        x = x.copy()
        x[i] = np.float32(i + 1)
        mgr.snapshot({"params": x}, step=i,
                     aux={"cursor": Cursor(next_index=i + 1).to_state(),
                          "round": i})
        if i == 0:
            early_refs = set(mgr.manifests[mgr.order[-1]].all_refs())
    tier = EdgeTier(store, [EdgeCache("edge-0"), EdgeCache("edge-1")])
    tr = VolunteerTrainer(grad_fn=None, apply_fn=None, state=None,
                          stream=None, micro_batches=1, snapshots=mgr,
                          edge=tier)
    next_step = tr.restore_latest({"params": np.zeros_like(x)},
                                  client_hashes=early_refs)
    assert next_step == 3
    assert np.array_equal(tr.state["params"], x)
    plan = tr.last_restore_plan
    assert plan["route"].startswith("edge-")
    assert plan["missing"] > 0 and plan["bytes_moved"] > 0


# ---------------------------------------------------------------------------
# shared Membership mixin
# ---------------------------------------------------------------------------
def test_membership_verbs_shared_across_planes():
    origin, refs, tier = _tier(3)
    rs = ReplicaSet(ChunkStore(), [ChunkStore()])
    for plane in (tier, rs):
        plane.mark_down(1)
        assert plane.is_down(1)
        with pytest.raises(ValueError):
            plane.promote(1)              # down member can't lead
        plane.mark_up(1)
        plane.promote(1)
        assert plane.primary_index == 1
        with pytest.raises(ValueError):
            plane.remove(1)               # never drop the primary
        with pytest.raises(IndexError):
            plane.mark_down(99)


def test_membership_remove_remaps_indices():
    origin, refs, tier = _tier(3)
    tier.mark_down(2)
    tier.promote(1)
    tier.remove(0)
    assert tier.primary_index == 0        # shifted down with the removal
    assert tier.is_down(1)                # old index 2 followed its member
    assert tier.cache_ids() == ["edge-1", "edge-2"]


# ---------------------------------------------------------------------------
# Wire protocol + deprecated shims
# ---------------------------------------------------------------------------
def test_chunkstore_satisfies_wire_protocol():
    assert isinstance(ChunkStore(), Wire)
    assert isinstance(EdgeCache("c").store, Wire)


def test_transfer_plan_unpacks_as_legacy_tuple():
    plan = TransferPlan(["r"], 5, 2)
    missing, moved, dedup = plan
    assert (missing, moved, dedup) == (["r"], 5, 2)
    assert plan[2] == 2 and len(plan) == 3 and bool(plan)
    assert not TransferPlan([], 0, 9)


def test_deprecated_shims_warn_and_delegate():
    origin, refs = _capsule()
    sink = ChunkStore(chunk_bytes=CHUNK)
    with pytest.deprecated_call():
        plan = origin.transfer_plan(refs, set())
    assert tuple(plan) == tuple(origin.plan_send(refs, set()))
    with pytest.deprecated_call():
        records = origin.export_records(plan.refs)
    assert records == origin.send(plan.refs)
    offered = {r: origin.object_size(r) for r in plan.refs}
    with pytest.deprecated_call():
        iplan = sink.ingest_plan(offered, client_id="c")
    assert tuple(iplan) == tuple(sink.plan_recv(offered, client_id="c"))
    with pytest.deprecated_call():
        written = sink.ingest(records, client_id="c")
    assert written > 0
    assert sink.resolve_buffer(refs) == origin.resolve_buffer(refs)


def test_replicaset_ingest_shim_still_enqueues():
    rs = ReplicaSet(ChunkStore(chunk_bytes=CHUNK),
                    [ChunkStore(chunk_bytes=CHUNK)])
    src = ChunkStore(chunk_bytes=CHUNK)
    ref = src.put(b"payload" * 100)
    with pytest.deprecated_call():
        rs.ingest(src.send([ref]))
    assert ref in rs.outbox               # replication still queued
    rs.pump()
    assert rs.members[1].has(ref)
