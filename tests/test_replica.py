"""Fault-injection tests for replicated snapshot chains (ReplicaSet +
ChurnSim).

The acceptance cycle, under three distinct seeds: run volunteer training
with per-round snapshots fanning out to peer stores through the bounded
outbox (with scripted message drops and reordered delivery), kill the
primary store with full disk loss after snapshot k, promote a replica,
and prove that ``restore_latest`` + one more training round on the
promoted store reproduces byte-identical state with zero lost committed
snapshots — while the simulator's step accounting shows replication never
did peer I/O on the snapshot hot path.
"""
import jax
import numpy as np
import pytest

from repro.core.chunkstore import ChunkStore, is_delta_ref
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.replica import ReplicaSet
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.server import Project, VBoincServer
from repro.core.sim import ChurnSim
from repro.core.snapshots import SnapshotManager
from repro.models import api

N = 8192                       # 32 KiB of f32 params -> 8 chunks of 4 KiB
CHUNK = 1 << 12


# ---------------------------------------------------------------------------
# toy deterministic training job (cheap; bitwise-reproducible rounds)
# ---------------------------------------------------------------------------
class ToyStream:
    def batch(self, index: int) -> dict:
        rng = np.random.default_rng(1000 + index)
        return {"x": rng.standard_normal(N).astype(np.float32)}


def _toy_grad(params, batch):
    diff = params["w"] - batch["x"]
    return float(np.mean(diff * diff)), {"w": (2.0 / N) * diff}


def _toy_apply(state, grads):
    m = (0.9 * state.opt["m"] + grads["w"]).astype(np.float32)
    w = (state.params["w"] - 0.1 * m).astype(np.float32)
    return api.TrainState({"w": w}, {"m": m})


def _toy_state():
    rng = np.random.default_rng(42)
    return api.TrainState({"w": rng.standard_normal(N).astype(np.float32)},
                          {"m": np.zeros(N, np.float32)})


def _abstract():
    return api.TrainState({"w": np.zeros(N, np.float32)},
                          {"m": np.zeros(N, np.float32)})


def _toy_trainer(snaps, seed=0):
    tr = VolunteerTrainer(grad_fn=_toy_grad, apply_fn=_toy_apply,
                          state=_toy_state(), stream=ToyStream(),
                          micro_batches=2, snapshots=snaps,
                          snapshot_every=1, seed=seed,
                          scheduler=VolunteerScheduler(clock=SimClock()))
    tr.add_worker(SimWorker("w0"))
    return tr


def _state_bytes(state) -> bytes:
    return np.concatenate(
        [np.asarray(leaf).reshape(-1).view(np.uint8)
         for leaf in jax.tree.leaves(state)]).tobytes()


def _golden(rounds: int) -> list[bytes]:
    """Reference run, no replication, no churn: state bytes per round."""
    tr = _toy_trainer(SnapshotManager(ChunkStore(chunk_bytes=CHUNK),
                                      keep_last=10))
    out = []
    for s in range(rounds):
        tr.round(s)
        out.append(_state_bytes(tr.state))
    return out


# ---------------------------------------------------------------------------
# acceptance: kill-primary -> promote -> restore -> resume, 3 seeds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kill_primary_promote_restore_resume(seed):
    k = 3                                        # kill after snapshot k
    golden = _golden(k + 2)

    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:], outbox_limit=256)
    sim = ChurnSim(rs, seed=seed)
    snaps = SnapshotManager(rs, keep_last=10)
    tr = _toy_trainer(snaps, seed=seed)

    for s in range(k + 1):                       # rounds 0..k, snapshot each
        sim.hot(lambda s=s: tr.round(s))
        if s == 1:
            sim.drop(1)                          # scripted message loss
        sim.pump()
        sim.deliver(shuffle=True)                # reordered delivery
    sim.settle()                                 # retries drain the drop
    assert not rs.outbox and not sim.in_flight

    committed = list(snaps.order)
    assert len(committed) == k + 1
    live = set(snaps.get_manifest(snaps.latest()).all_refs())
    for r in rs.live_closure_all(live):
        assert rs.replication_factor(r) == 3     # fully fanned out

    sim.kill(0, wipe=True)                       # primary disk loss
    promoted = sim.promote()
    assert promoted != 0

    # zero lost committed snapshots: every retained manifest still restores
    for sid in committed:
        state, _ = snaps.restore(sid, target_tree=_abstract())
        assert _state_bytes(state)               # resolvable, hash-verified

    tr2 = _toy_trainer(snaps, seed=seed + 100)
    next_step = tr2.restore_latest(_abstract())
    assert next_step == k + 1
    assert _state_bytes(tr2.state) == golden[k]  # byte-identical restore

    # one more round against the promoted store reproduces the reference
    sim.hot(lambda: tr2.round(next_step))
    sim.pump()
    sim.deliver(shuffle=False)
    assert _state_bytes(tr2.state) == golden[k + 1]

    # replication never did peer I/O inside a hot step (step accounting)
    assert sim.peer_ingests_during_hot_steps() == []
    # ...but peers did real ingest work during net steps
    assert any(e[1] == "net" and e[2] != e[3] for e in sim.ingest_log)


# ---------------------------------------------------------------------------
# read repair: torn/missing primary objects heal from a peer in place
# ---------------------------------------------------------------------------
def test_read_repair_heals_torn_chain(tmp_path):
    primary = ChunkStore(tmp_path / "p0", chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer])

    base = np.zeros(CHUNK, np.uint8)
    base[:16] = 7
    h = rs.put(base.tobytes())
    new = base.copy()
    new[100] = 9
    dref = rs.put_delta(h, (base ^ new).tobytes(), full_bytes=new.tobytes())
    assert is_delta_ref(dref)
    rs.flush()
    assert rs.replication_factor(h) == 2 and rs.replication_factor(dref) == 2

    # tear the primary's base object mid-file (simulated partial write)
    p = tmp_path / "p0" / "objects" / h[:2] / h[2:]
    p.write_bytes(p.read_bytes()[:100])
    assert rs.resolve(dref) == new.tobytes()     # healed from the peer
    assert rs.rstats["repaired"] >= 1
    assert primary.get(h) == base.tobytes()      # healed IN PLACE, verified

    # a deleted delta record heals too (chain depth re-validated by ingest)
    dh = dref[2:]
    (tmp_path / "p0" / "deltas" / dh[:2] / dh[2:]).unlink()
    primary._depths.clear()
    assert rs.resolve(dref) == new.tobytes()
    assert primary.ref_depth(dref) == 1


def test_read_repair_without_any_replica_raises():
    primary = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [])
    h = rs.put(b"x" * 100)
    primary.wipe()
    with pytest.raises(IOError):
        rs.resolve(h)
    assert rs.rstats["repair_failed"] == 1


# ---------------------------------------------------------------------------
# GC marks the closure across the whole set
# ---------------------------------------------------------------------------
def test_gc_keeps_peer_parent_alive_for_primary_only_delta():
    primary = ChunkStore(chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer])

    base = np.zeros(CHUNK, np.uint8)
    base[:32] = 5
    h = rs.put(base.tobytes())
    rs.flush()                                   # parent lives on both
    new = base.copy()
    new[64] = 6
    dref = rs.put_delta(h, (base ^ new).tobytes(), full_bytes=new.tobytes())
    assert is_delta_ref(dref) and not peer.has(dref)   # not pumped yet
    garbage = peer.put(b"Z" * 64)                # peer-local junk

    rs.gc({dref})
    # the delta record exists only on the primary, yet the peer keeps the
    # parent the primary still references; the peer sweep is deferred to
    # the next pump (no peer I/O inside the synchronous gc call)
    assert peer.has(h) and peer.has(garbage)
    assert primary.has(h) and primary.has(dref)

    rs.flush()                                   # outbox survived the gc;
    assert peer.has(dref)                        # deferred sweep applied
    assert peer.has(h) and not peer.has(garbage)
    assert rs.replication_factor(dref) == 2


# ---------------------------------------------------------------------------
# a down member defers its refs: no silent drain of the outbox
# ---------------------------------------------------------------------------
def test_pump_defers_refs_for_down_peer_no_silent_loss():
    primary = ChunkStore(chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer], outbox_limit=32)

    rs.mark_down(1)                              # peer offline
    h = rs.put(b"precious" * 100)
    rs.pump()
    assert rs.rstats["deferred"] >= 1            # parked, NOT drained
    assert rs.replication_report([h])["parked"] == 1
    assert not peer.has(h)
    rs.pump()                                    # no churn while parked
    assert rs.rstats["deferred"] == 1

    rs.mark_up(1)                                # peer returns
    assert h in rs.outbox                        # parked refs re-queued
    rs.pump()
    assert not rs.outbox                         # now fanned out
    assert rs.replication_factor(h) == 2
    assert rs.replication_report([h])["parked"] == 0


def test_sync_delivery_survives_deferred_gc_sweep():
    """A keep set recorded by gc must not revert objects that sync (or a
    delayed transport) delivered to a peer after the gc ran."""
    primary = ChunkStore(chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer], outbox_limit=1)
    a = rs.put(b"a" * 64)
    rs.flush()
    rs.gc({a})                                   # peer sweep deferred
    b = rs.put(b"b" * 64)                        # b overflows the outbox
    c = rs.put(b"c" * 64)
    assert rs.rstats["outbox_dropped"] >= 1
    rs.sync()                                    # repairs b (and c)
    assert peer.has(b) and peer.has(c)
    rs.pump()                                    # stale keep={a} must not
    assert peer.has(b) and peer.has(c)           # undo the repair
    assert rs.replication_factor(b) == 2


def test_park_dedups_refs_under_flaky_alive_peer():
    """A ref retried because an alive peer's sends keep failing must be
    parked once per down member, not once per retry."""
    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:],
                    transport=lambda i, recs: False)   # alive sends fail
    rs.mark_down(2)
    refs = [rs.put(bytes([65 + i]) * 64) for i in range(3)]
    for _ in range(5):
        rs.pump()                                # refs keep retrying
    parked = list(rs._parked[2])
    assert sorted(parked) == sorted(refs)        # each owed exactly once
    assert rs.rstats["deferred"] == 3


def test_remove_dead_member_and_promote_bounds():
    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:])
    h = rs.put(b"data" * 64)
    rs.flush()

    with pytest.raises(IndexError):
        rs.promote(7)                            # out of range: no damage
    assert rs.primary_index == 0
    with pytest.raises(ValueError):
        rs.remove(0)                             # primary is protected

    rs.mark_down(1)
    rs.put(b"more" * 64)
    rs.pump()                                    # parks a ref for member 1
    rs.remove(1)                                 # volunteer never returns
    assert len(rs.members) == 2 and rs.primary_index == 0
    assert rs._parked == {}                      # its parked queue is gone
    rs.flush()
    assert rs.replication_factor(h) == 2         # survivor set still works

    # failover to a bogus index must not brick a healthy primary
    server = VBoincServer(rs)
    with pytest.raises(IndexError):
        server.failover(index=9)
    assert rs.primary_index == 0
    assert rs.resolve(h)                         # primary still serving


# ---------------------------------------------------------------------------
# bounded outbox: a dead peer never blocks or grows the hot path
# ---------------------------------------------------------------------------
def test_bounded_outbox_never_blocks_and_sync_repairs():
    primary = ChunkStore(chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer], outbox_limit=8,
                    transport=lambda i, recs: False)   # peer unreachable

    refs = [rs.put(np.random.default_rng(i).bytes(256)) for i in range(20)]
    assert len(rs.outbox) <= 8                   # bounded under outage
    assert rs.rstats["outbox_dropped"] >= 12
    rs.pump()                                    # all sends fail, no raise
    assert rs.rstats["send_failed"] > 0
    assert len(rs.outbox) <= 8
    assert not list(peer.all_refs())

    rs.transport = None                          # link restored
    rs.sync()                                    # anti-entropy closes gaps
    for r in refs:
        assert rs.replication_factor(r) == 2


# ---------------------------------------------------------------------------
# server failover: promoted replica serves fetch_capsule / report_result
# ---------------------------------------------------------------------------
def test_server_failover_serves_fetch_and_results():
    from repro.core.capsule import CapsuleSpec
    from repro.models.lm import RunConfig

    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:])
    server = VBoincServer(rs)
    mgr = SnapshotManager(rs, keep_last=5, auto_gc=False)
    x = np.random.default_rng(5).standard_normal(N).astype(np.float32)
    mgr.snapshot({"params": x}, step=0)

    spec = CapsuleSpec("qwen2-1.5b", "train_4k", RunConfig())
    proj = Project("lm", spec, scheduler=VolunteerScheduler(clock=SimClock()))
    proj.snapshots = mgr
    server.publish(proj)
    key = server.register_user("vol")
    rs.flush()

    stores[0].wipe()                             # primary disk dies
    promoted = server.failover()
    assert promoted != 0 and rs.primary_index == promoted

    _, missing, moved = server.fetch_capsule("lm", set(), key)
    assert missing and moved > x.nbytes // 2     # still serving, full state
    refs = mgr.get_manifest(mgr.latest()).tensors["['params']"].refs
    got = np.frombuffer(rs.resolve_buffer(refs), np.float32)
    assert np.array_equal(got.view(np.uint8), x.view(np.uint8))

    proj.scheduler.join("w")
    proj.scheduler.submit(0, {})
    unit = server.request_work("lm", "w")
    assert unit is not None
    assert server.report_result("lm", "w", 0, "h")   # results keep flowing

    with pytest.raises(RuntimeError):
        VBoincServer(ChunkStore()).failover()    # unreplicated store


# ---------------------------------------------------------------------------
# production mode: the background pump drains the outbox on its own
# ---------------------------------------------------------------------------
def test_background_pump_thread_replicates():
    primary = ChunkStore(chunk_bytes=CHUNK)
    peer = ChunkStore(chunk_bytes=CHUNK)
    rs = ReplicaSet(primary, [peer])
    rs.start(interval_s=0.001)
    try:
        refs = [rs.put(np.random.default_rng(i).bytes(512))
                for i in range(10)]
    finally:
        rs.stop()                                # joins, then final flush
    assert rs._thread is None
    for r in refs:
        assert rs.replication_factor(r) == 2
    rs.stop()                                    # idempotent
    report = rs.replication_report(refs)
    assert report["min_factor"] == 2 and report["fully_replicated"] == 10
    assert report["outbox"] == 0


# ---------------------------------------------------------------------------
# revive + anti-entropy: a wiped member catches back up
# ---------------------------------------------------------------------------
def test_revived_member_catches_up_via_sync():
    stores = [ChunkStore(chunk_bytes=CHUNK) for _ in range(3)]
    rs = ReplicaSet(stores[0], stores[1:])
    sim = ChurnSim(rs, seed=7)
    snaps = SnapshotManager(rs, keep_last=10)
    tr = _toy_trainer(snaps)

    sim.hot(lambda: tr.round(0))
    sim.settle()
    sim.kill(2, wipe=True)                       # peer 2 loses its disk
    sim.hot(lambda: tr.round(1))                 # writes continue
    sim.settle()
    live = set(snaps.get_manifest(snaps.latest()).all_refs())
    closure = rs.live_closure_all(live)
    assert all(rs.replication_factor(r) == 2 for r in closure)

    sim.revive(2, sync=True)                     # anti-entropy catch-up
    assert all(rs.replication_factor(r) == 3 for r in closure)
    # the revived member alone can reconstruct the snapshot
    man = snaps.get_manifest(snaps.latest())
    key = next(k for k in man.tensors if "params" in k)
    rs2 = ReplicaSet(stores[2])
    data = rs2.resolve_buffer(man.tensors[key].refs)
    assert data == np.asarray(tr.state.params["w"]).tobytes()
