"""Gradient-compression invariants: bounded error, error-feedback recovery,
4x wire savings."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim.grad_compress import (compress, decompress, init_error,
                                       wire_bytes)


def _tree(seed=0, scale=1.0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((300, 70)) * scale,
                             jnp.float32),
            "b": jnp.asarray(r.standard_normal(1000) * scale, jnp.float32)}


def test_roundtrip_error_bounded():
    g = _tree()
    c, err = compress(g, init_error(g))
    back = decompress(c, g)
    for k in g:
        # int8 per-block: relative error ~ 1/127 of the block max
        denom = np.abs(np.asarray(g[k])).max()
        assert np.abs(np.asarray(back[k] - g[k])).max() <= denom / 127 + 1e-6
        np.testing.assert_allclose(np.asarray(err[k]),
                                   np.asarray(g[k] - back[k]), atol=1e-6)


def test_error_feedback_sums_correctly():
    """Over many steps, sum(decompressed) ≈ sum(true grads): the residual
    never escapes (classic EF-SGD property)."""
    g0 = _tree(seed=0)
    err = init_error(g0)
    total_true = {k: np.zeros(g0[k].shape, np.float32) for k in g0}
    total_sent = {k: np.zeros(g0[k].shape, np.float32) for k in g0}
    for step in range(30):
        g = _tree(seed=step)
        c, err = compress(g, err)
        d = decompress(c, g)
        for k in g:
            total_true[k] += np.asarray(g[k])
            total_sent[k] += np.asarray(d[k])
    for k in total_true:
        # sent + residual-in-flight == true sum, to numerical noise
        drift = np.abs(total_sent[k] + np.asarray(err[k]) - total_true[k])
        assert drift.max() < 1e-3


def test_wire_savings():
    g = _tree()
    raw, comp = wire_bytes(g)
    assert raw / comp > 3.5          # ~4x minus scale overhead


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2 ** 31),
       scale=st.floats(1e-6, 1e4))
def test_compress_property(n, seed, scale):
    r = np.random.default_rng(seed)
    g = {"x": jnp.asarray(r.standard_normal(n) * scale, jnp.float32)}
    c, err = compress(g, init_error(g))
    back = decompress(c, g)
    assert back["x"].shape == g["x"].shape
    bound = np.abs(np.asarray(g["x"])).max() / 100 + 1e-6
    assert np.abs(np.asarray(back["x"] - g["x"])).max() <= bound
