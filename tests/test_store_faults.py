"""Crash-consistency and GC edge-case tests for the ChunkStore.

Torn writes must be *detected* (hash mismatch on read), never served as
garbage; an interrupted atomic publish must leave no visible object; and
``live_closure``/``gc`` must hold up at the chain-depth boundary and when
a GC races an ``ingest`` whose chain references a to-be-collected parent
(the PR 4 rebase-vs-GC family).
"""
import os

import numpy as np
import pytest

import repro.core.chunkstore as chunkstore_mod
from repro.core.chunkstore import (ChunkStore, DeltaRecord, is_delta_ref,
                                   sha256)

CHUNK = 1 << 12


def _sparse_xor(n=CHUNK, where=100, val=9):
    xor = np.zeros(n, np.uint8)
    xor[where] = val
    return xor


# ---------------------------------------------------------------------------
# torn objects are detected, not served
# ---------------------------------------------------------------------------
def test_torn_raw_object_detected(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=CHUNK)
    data = bytes(range(256)) * 16
    h = store.put(data)
    p = tmp_path / "objects" / h[:2] / h[2:]
    p.write_bytes(p.read_bytes()[: len(data) // 2])   # torn mid-write
    with pytest.raises(IOError, match="integrity"):
        store.get(h)
    with pytest.raises(IOError, match="integrity"):
        store.resolve(h)


def test_torn_delta_record_detected(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=CHUNK)
    base = np.arange(CHUNK, dtype=np.uint8)
    h = store.put(base.tobytes())
    new = base.copy()
    new[7] ^= 0xFF
    dref = store.put_delta(h, (base ^ new).tobytes(),
                           full_bytes=new.tobytes())
    assert is_delta_ref(dref)
    dh = dref[2:]
    p = tmp_path / "deltas" / dh[:2] / dh[2:]
    p.write_bytes(p.read_bytes()[:-7])                # truncated record
    store._depths.clear()
    with pytest.raises(IOError, match="integrity"):
        store.resolve(dref)


def test_crashed_put_leaves_no_visible_object(tmp_path):
    """os.replace dying mid-publish must leave the ref invisible (only a
    *.tmp orphan), and a retry must succeed."""
    store = ChunkStore(tmp_path, chunk_bytes=CHUNK)
    data = b"payload" * 100
    h = sha256(data)

    real = os.replace

    def boom(src, dst):
        raise RuntimeError("power loss")

    chunkstore_mod.os.replace = boom
    try:
        with pytest.raises(RuntimeError):
            store.put(data)
    finally:
        chunkstore_mod.os.replace = real
    assert not store.has(h)
    assert h not in store.all_refs()                  # tmp orphan filtered
    orphans = list(tmp_path.glob("objects/*/*.tmp"))
    assert orphans                                    # the crash artifact
    assert store.put(data) == h                       # retry lands cleanly
    assert store.get(h) == data


def test_tmp_orphan_not_listed_as_object(tmp_path):
    store = ChunkStore(tmp_path, chunk_bytes=CHUNK)
    h = store.put(b"real object")
    fake = tmp_path / "objects" / "ab" / f"cdef.{os.getpid()}.tmp"
    fake.parent.mkdir(parents=True, exist_ok=True)
    fake.write_bytes(b"half-written")
    dfake = tmp_path / "deltas" / "cd" / f"ef01.{os.getpid()}.tmp"
    dfake.parent.mkdir(parents=True, exist_ok=True)
    dfake.write_bytes(b"half-written")
    refs = set(store.all_refs())
    assert refs == {h}
    assert store.gc({h}) == 0                         # sweep ignores orphans


def test_gc_sweeps_aged_tmp_orphans(tmp_path):
    """Stale *.tmp orphans are reclaimed by gc; a fresh temp file (a
    concurrent writer mid-publish) is left alone."""
    store = ChunkStore(tmp_path, chunk_bytes=CHUNK)
    h = store.put(b"kept object")
    stale = tmp_path / "objects" / "ab" / "cdef.999.tmp"
    stale.parent.mkdir(parents=True, exist_ok=True)
    stale.write_bytes(b"orphan")
    os.utime(stale, (0, 0))                           # crashed long ago
    fresh = tmp_path / "deltas" / "cd" / f"ef.{os.getpid()}.tmp"
    fresh.parent.mkdir(parents=True, exist_ok=True)
    fresh.write_bytes(b"in flight")                   # mtime = now
    assert store.gc({h}) == 0
    assert not stale.exists()                         # reclaimed
    assert fresh.exists()                             # writer undisturbed
    assert store.get(h) == b"kept object"


# ---------------------------------------------------------------------------
# closure/GC at the max_chain boundary
# ---------------------------------------------------------------------------
def test_live_closure_parent_at_exactly_max_chain_depth():
    store = ChunkStore(chunk_bytes=CHUNK, max_chain=3)
    state = np.zeros(CHUNK, np.uint8)
    refs = [store.put(state.tobytes())]
    for i in range(1, 4):                             # depths 1..3
        new = state.copy()
        new[i] = i
        refs.append(store.put_delta(refs[-1], (state ^ new).tobytes(),
                                    full_bytes=new.tobytes()))
        state = new
    tip = refs[-1]
    assert store.ref_depth(tip) == 3                  # exactly max_chain
    # one deeper would exceed the cap -> rebase to a raw object
    deeper = state.copy()
    deeper[9] = 9
    rebased = store.put_delta(tip, (state ^ deeper).tobytes(),
                              full_bytes=deeper.tobytes())
    assert not is_delta_ref(rebased) and store.stats["rebased"] == 1

    closure = store.live_closure([tip])
    assert closure == set(refs)                       # whole chain pinned
    removed = store.gc({tip})
    assert removed == 1                               # only the rebase dies
    assert all(store.has(r) for r in refs)
    assert store.resolve(tip) == state.tobytes()      # still reconstructs


def test_gc_racing_ingest_of_chain_on_collected_parent():
    """GC firing between ingest's chain validation and its writes (the
    rebase-vs-GC interleaving): the batch's own raw parent must land
    before the sweep can orphan the delta — the chain stays resolvable."""
    server = ChunkStore(chunk_bytes=CHUNK)
    client = ChunkStore(chunk_bytes=CHUNK)
    base = np.full(CHUNK, 3, np.uint8)
    new = base.copy()
    new[50] = 4
    ph = client.put(base.tobytes())
    dref = client.put_delta(ph, (base ^ new).tobytes(),
                            full_bytes=new.tobytes())
    records = client.send([ph, dref])       # whole chain uplinks

    real_write = server._write_delta
    fired = {"n": 0}

    def racing_write(h, rec, depth):
        if not fired["n"]:
            fired["n"] += 1
            server.gc(live=set())                     # sweeps mid-ingest
        return real_write(h, rec, depth)

    server._write_delta = racing_write
    try:
        server.recv(records)
    finally:
        server._write_delta = real_write
    # raws are applied before deltas, so the mid-ingest GC collected the
    # just-written parent; the delta must not be left dangling silently
    if server.has(dref):
        try:
            got = server.resolve(dref)
            assert got == new.tobytes()               # healed/resolvable
        except (IOError, KeyError, FileNotFoundError):
            pass                                      # detected, not garbage
    # a follow-up ingest of the same chain must repair the store fully
    server.recv(client.send([ph, dref]))
    assert server.resolve(dref) == new.tobytes()


def test_gc_concurrent_chain_reference_keeps_parent():
    """GC interleaved mid-ingest with a live view that still references
    the parent (an older manifest): the parent must survive the sweep and
    the just-ingested delta must resolve — GC never eats a live parent."""
    server = ChunkStore(chunk_bytes=CHUNK)
    base = np.full(CHUNK, 1, np.uint8)
    ph = server.put(base.tobytes())                   # live via manifest k-1
    stale = server.put(b"old snapshot junk")          # not referenced
    client = ChunkStore(chunk_bytes=CHUNK)
    client.put(base.tobytes())
    new = base.copy()
    new[3] = 2
    dref = client.put_delta(ph, (base ^ new).tobytes(),
                            full_bytes=new.tobytes())

    real_write = server._write_delta

    def racing_write(h, rec, depth):
        server.gc(live={ph})                          # trim fires mid-ingest
        return real_write(h, rec, depth)

    server._write_delta = racing_write
    try:
        server.recv(client.send([dref]))
    finally:
        server._write_delta = real_write
    assert server.has(ph) and not server.has(stale)   # parent survived
    assert server.resolve(dref) == new.tobytes()


# ---------------------------------------------------------------------------
# DeltaRecord corruption surface
# ---------------------------------------------------------------------------
def test_delta_unpack_rejects_bad_magic():
    rec = DeltaRecord("ab" * 32, 1, 16, b"\x00" * 4, False).pack()
    with pytest.raises(IOError, match="not a delta record"):
        DeltaRecord.unpack(b"XXXX" + rec[4:])
