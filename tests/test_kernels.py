"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across a
shape/dtype sweep (the assignment's kernel deliverable)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.delta_encode.ops import diff_blocks, patch_blocks
from repro.kernels.flash_attention.ops import attend
from repro.kernels.pcor.ops import correlate, pcor_strip
from repro.kernels.pcor.ref import pcor_ref
from repro.kernels.ssm_scan.ops import selective_scan

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, T, S, H, K, hd, causal)
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 384, 8, 8, 32, False),
    (2, 200, 200, 6, 3, 64, True),      # non-block-multiple T/S
    (1, 96, 96, 4, 1, 128, False),      # MQA
    (1, 64, 64, 2, 2, 256, True),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_ref(case):
    b, t, s, h, k, hd, causal = case
    q = RNG.standard_normal((b, t, h, hd)).astype(np.float32)
    kk = RNG.standard_normal((b, s, k, hd)).astype(np.float32)
    v = RNG.standard_normal((b, s, k, hd)).astype(np.float32)
    out = attend(q, kk, v, causal=causal, mode="interpret")
    ref = attend(q, kk, v, causal=causal, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jnp.asarray(RNG.standard_normal((1, 128, 4, 64)), dtype=dtype)
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), dtype=dtype)
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), dtype=dtype)
    out = attend(q, k, v, causal=True, mode="interpret")
    ref = attend(q, k, v, causal=True, mode="ref")
    assert out.dtype == ref.dtype == jnp.dtype(dtype)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_blocks_sweep():
    q = RNG.standard_normal((1, 256, 2, 64)).astype(np.float32)
    k = RNG.standard_normal((1, 256, 2, 64)).astype(np.float32)
    v = RNG.standard_normal((1, 256, 2, 64)).astype(np.float32)
    ref = attend(q, k, v, causal=True, mode="ref")
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = attend(q, k, v, causal=True, block_q=bq, block_k=bk,
                     mode="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
SSM_CASES = [(2, 64, 256, 16), (1, 50, 130, 8), (3, 32, 128, 16),
             (2, 128, 384, 4), (1, 33, 257, 16)]


@pytest.mark.parametrize("case", SSM_CASES)
def test_ssm_scan_vs_ref(case):
    b, t, di, n = case
    x = RNG.standard_normal((b, t, di)).astype(np.float32)
    dt = np.abs(RNG.standard_normal((b, t, di))).astype(np.float32) * 0.1
    bm = RNG.standard_normal((b, t, n)).astype(np.float32)
    cm = RNG.standard_normal((b, t, n)).astype(np.float32)
    a = -np.abs(RNG.standard_normal((di, n))).astype(np.float32)
    out = selective_scan(x, dt, bm, cm, a, mode="interpret")
    ref = selective_scan(x, dt, bm, cm, a, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_block_sweep():
    b, t, di, n = 1, 64, 256, 16
    x = RNG.standard_normal((b, t, di)).astype(np.float32)
    dt = np.abs(RNG.standard_normal((b, t, di))).astype(np.float32) * 0.1
    bm = RNG.standard_normal((b, t, n)).astype(np.float32)
    cm = RNG.standard_normal((b, t, n)).astype(np.float32)
    a = -np.abs(RNG.standard_normal((di, n))).astype(np.float32)
    ref = selective_scan(x, dt, bm, cm, a, mode="ref")
    for bt, bd in [(16, 128), (32, 256), (64, 128)]:
        out = selective_scan(x, dt, bm, cm, a, block_t=bt, block_di=bd,
                             mode="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# delta encode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,shape", [
    (np.float32, (1000, 517)), (np.float32, (8192,)),
    (np.int32, (3, 8193)), (np.float32, (7,)),
])
def test_delta_roundtrip_bit_exact(dtype, shape):
    if dtype == np.float32:
        old = RNG.standard_normal(shape).astype(dtype)
    else:
        old = RNG.integers(-2 ** 30, 2 ** 30, shape).astype(dtype)
    new = old.copy()
    flat = new.reshape(-1)
    idx = RNG.choice(flat.size, size=max(1, flat.size // 50), replace=False)
    flat[idx] = flat[idx] * 2 + 1
    tiles, bitmap, _ = diff_blocks(old, new, mode="interpret")
    rec = patch_blocks(old, tiles, bitmap, mode="interpret")
    assert np.array_equal(rec.view(np.uint8), new.view(np.uint8))
    t2, b2, _ = diff_blocks(old, new, mode="ref")
    assert np.array_equal(bitmap, b2) and np.array_equal(tiles, t2)


def test_delta_unchanged_is_empty():
    x = np.ones(30_000, np.float32)
    tiles, bitmap, _ = diff_blocks(x, x.copy(), mode="interpret")
    assert tiles.shape[0] == 0 and bitmap.sum() == 0


def test_delta_nan_inf_exact():
    old = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0] * 2000, np.float32)
    new = old.copy()
    new[::7] = 1.5
    tiles, bitmap, _ = diff_blocks(old, new, mode="interpret")
    rec = patch_blocks(old, tiles, bitmap, mode="interpret")
    assert np.array_equal(rec.view(np.uint8), new.view(np.uint8))


# ---------------------------------------------------------------------------
# pcor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g,s", [(150, 321), (256, 128), (100, 50), (64, 7)])
def test_pcor_vs_numpy(g, s):
    x = RNG.standard_normal((g, s)).astype(np.float32)
    out = np.asarray(correlate(x, mode="interpret"))
    np.testing.assert_allclose(out, np.asarray(pcor_ref(x)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out, np.corrcoef(x), rtol=1e-4, atol=1e-4)
    assert np.allclose(np.diag(out), 1.0, atol=1e-5)


def test_pcor_strips_tile_the_matrix():
    x = RNG.standard_normal((200, 64)).astype(np.float32)
    full = np.asarray(correlate(x, mode="ref"))
    a = np.asarray(pcor_strip(x, 0, 100))
    b = np.asarray(pcor_strip(x, 100, 100))
    np.testing.assert_allclose(np.concatenate([a, b]), full,
                               rtol=1e-5, atol=1e-5)
