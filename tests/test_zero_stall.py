"""Zero-stall snapshot pipeline: fused-kernel parity, probe semantics,
async-writer crash consistency, and writer-vs-GC-vs-pump interleaving.

The fused probe+gather kernel runs here in ``interpret`` mode (CPU) and is
checked bit-for-bit against the numpy oracle (``ref``); the async writer
paths use ``ref`` mode so every assertion is deterministic.
"""
from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chunkstore import ChunkStore
from repro.core.replica import ReplicaSet
from repro.core.snapshots import SnapshotManager
from repro.kernels.delta_encode.kernel import fused_delta_records
from repro.kernels.delta_encode.ops import (KERNEL_DTYPES, KERNEL_STATS,
                                            DeviceMirror, changed_blocks,
                                            probe_leaves, reset_kernel_stats)
from repro.kernels.delta_encode.ref import fused_records_ref


def _mutate(arr: np.ndarray, idx, rng) -> np.ndarray:
    out = arr.copy()
    if np.issubdtype(out.dtype, np.integer):
        out[idx] = out[idx] + 1
    else:
        out[idx] = (rng.standard_normal(len(idx)) + 2.0).astype(out.dtype)
    return out


def _assert_fused_parity(old_np: np.ndarray, new_np: np.ndarray) -> None:
    """interpret-mode fused kernel == numpy oracle, bitmap and tiles."""
    bm_ref, tiles_ref = fused_records_ref(old_np, new_np)
    bm_dev, tiles_dev, n = fused_delta_records(
        jnp.asarray(old_np), jnp.asarray(new_np), interpret=True)
    bm_dev = np.asarray(bm_dev)
    np.testing.assert_array_equal(bm_dev, bm_ref)
    k = int(bm_dev.sum())
    np.testing.assert_array_equal(np.asarray(tiles_dev)[:k], tiles_ref)
    assert int(n) == -(-old_np.nbytes // 4)    # i32 image length


# sizes chosen to land on tile boundaries and well off them: sub-tile,
# tail after 3 whole 8192-element tiles, and a large ragged tail
TAIL_SIZES = (1000, 8192 * 3 + 5, 70000)


@pytest.mark.parametrize("size", TAIL_SIZES)
def test_fused_parity_tail_tiles(size):
    rng = np.random.default_rng(size)
    old = rng.standard_normal(size).astype(np.float32)
    new = _mutate(old, rng.integers(0, size, 17), rng)
    _assert_fused_parity(old, new)


@pytest.mark.parametrize("dtype", KERNEL_DTYPES)
def test_fused_parity_every_kernel_dtype(dtype):
    rng = np.random.default_rng(3)
    size = 8192 + 777                     # one whole tile + ragged tail
    base = rng.integers(-1000, 1000, size)
    old = np.asarray(jnp.asarray(base).astype(dtype))
    new = old.copy()
    idx = rng.integers(0, size, 9)
    new[idx] = np.asarray(jnp.asarray(base[idx] + 7).astype(dtype))
    _assert_fused_parity(old, new)


def test_fused_parity_empty_bitmap():
    old = np.arange(20000, dtype=np.int32)
    bm, tiles, _ = fused_delta_records(jnp.asarray(old), jnp.asarray(old),
                                       interpret=True)
    assert int(np.asarray(bm).sum()) == 0
    _assert_fused_parity(old, old.copy())


def test_fused_parity_all_changed():
    old = np.arange(8192 * 2 + 123, dtype=np.int32)
    new = old + 1                           # every tile flips
    bm_ref, _ = fused_records_ref(old, new)
    assert bm_ref.all()
    _assert_fused_parity(old, new)


# ---------------------------------------------------------------- probe


def _tree(rng) -> dict:
    # several size classes so leaves land in different pow2 buckets
    return {
        "tiny": rng.standard_normal(500).astype(np.float32),
        "small": rng.standard_normal(9000).astype(np.float32),
        "mid_a": rng.standard_normal(33000).astype(np.float32),
        "mid_b": rng.standard_normal(33000).astype(np.float32),
        "big": rng.standard_normal(131072).astype(np.float32),
    }


def test_probe_seeds_then_diffs_like_changed_blocks():
    rng = np.random.default_rng(11)
    t0 = _tree(rng)
    mirror = DeviceMirror()
    first = probe_leaves(t0, mode="ref", mirror=mirror)
    assert all(v is None for v in first.values())   # everything re-bases

    t1 = {k: (_mutate(v, rng.integers(0, v.size, 5), rng)
              if k in ("small", "big") else v.copy())
          for k, v in t0.items()}
    second = probe_leaves(t1, mode="ref", mirror=mirror)
    for key, v in t1.items():
        tiles, bitmap, nbytes = second[key]
        assert nbytes == v.nbytes
        ref_tiles, ref_bm, _ = changed_blocks(t0[key], v, mode="ref",
                                              fused=False)
        np.testing.assert_array_equal(bitmap.astype(bool),
                                      ref_bm.astype(bool))
        np.testing.assert_array_equal(tiles, ref_tiles)
        if key not in ("small", "big"):
            assert not bitmap.any()


def test_probe_bucketed_equals_per_leaf():
    rng = np.random.default_rng(12)
    t0 = _tree(rng)
    t1 = {k: _mutate(v, rng.integers(0, v.size, 3), rng)
          for k, v in t0.items()}
    mb, ml = DeviceMirror(), DeviceMirror()
    probe_leaves(t0, mode="ref", mirror=mb, bucketed=True)
    probe_leaves(t0, mode="ref", mirror=ml, bucketed=False)
    rb = probe_leaves(t1, mode="ref", mirror=mb, bucketed=True)
    rl = probe_leaves(t1, mode="ref", mirror=ml, bucketed=False)
    for key in t1:
        np.testing.assert_array_equal(rb[key][0], rl[key][0])
        np.testing.assert_array_equal(rb[key][1], rl[key][1])
        assert rb[key][2] == rl[key][2]


def test_probe_launches_o_buckets_not_o_leaves():
    rng = np.random.default_rng(13)
    tree = {f"l{i:02d}": rng.standard_normal(9000).astype(np.float32)
            for i in range(24)}              # 24 leaves, ONE size bucket
    mirror = DeviceMirror()
    probe_leaves(tree, mode="ref", mirror=mirror)
    nxt = {k: _mutate(v, [0], rng) for k, v in tree.items()}
    reset_kernel_stats()
    probe_leaves(nxt, mode="ref", mirror=mirror)
    assert KERNEL_STATS["launches"] == 1
    reset_kernel_stats()


def test_probe_identity_fast_path_skips_launch_for_immutable():
    rng = np.random.default_rng(14)
    frozen = {k: jnp.asarray(v) for k, v in _tree(rng).items()}
    mirror = DeviceMirror()
    probe_leaves(frozen, mode="ref", mirror=mirror)
    probe_leaves(frozen, mode="ref", mirror=mirror)   # build both buffers
    reset_kernel_stats()
    res = probe_leaves(frozen, mode="ref", mirror=mirror)  # same objects
    assert KERNEL_STATS["launches"] == 0
    assert all(not r[1].any() for r in res.values())
    reset_kernel_stats()


def test_probe_no_fast_path_for_writeable_numpy():
    """An in-place mutation of a writeable numpy leaf MUST be detected —
    object identity alone never short-circuits mutable arrays."""
    arr = np.zeros(9000, np.float32)
    mirror = DeviceMirror()
    probe_leaves({"a": arr}, mode="ref", mirror=mirror)
    arr[123] = 5.0                        # same object, new bytes
    tiles, bitmap, _ = probe_leaves({"a": arr}, mode="ref",
                                    mirror=mirror)["a"]
    assert bitmap.any() and tiles.size


def test_probe_layout_change_rebases_bucket():
    rng = np.random.default_rng(15)
    t0 = {"a": rng.standard_normal(9000).astype(np.float32),
          "b": rng.standard_normal(9000).astype(np.float32)}
    mirror = DeviceMirror()
    probe_leaves(t0, mode="ref", mirror=mirror)
    t1 = {"a": t0["a"].reshape(-1)[:4500].copy(), "b": t0["b"].copy()}
    res = probe_leaves(t1, mode="ref", mirror=mirror)
    assert res["a"] is None               # shape changed -> re-base
    # b shared a's bucket before the change; re-seeding is allowed, but
    # the round after must diff again
    t2 = {"a": t1["a"], "b": _mutate(t1["b"], [7], rng)}
    res2 = probe_leaves(t2, mode="ref", mirror=mirror)
    assert res2["b"] is not None and res2["b"][1].any()


# ------------------------------------------------------- async writer


def _state(rng, bump: int = 0) -> dict:
    w = rng.standard_normal(30000).astype(np.float32)
    return {"w": w + bump, "m": rng.standard_normal(9000).astype(np.float32)}


def test_async_manifests_byte_identical_to_inline():
    seq = []
    rng = np.random.default_rng(21)
    state = _state(rng)
    for i in range(5):
        idx = rng.integers(0, state["w"].size, 40)
        w = state["w"].copy()
        w[idx] += 1.0
        state = {"w": w, "m": state["m"]}
        seq.append(state)

    def run(async_mode):
        mgr = SnapshotManager(ChunkStore(), keep_last=10,
                              async_mode=async_mode, delta_mode="ref")
        for i, st in enumerate(seq):
            mgr.snapshot(st, step=i, block=True)
        refs = [mgr.manifests[sid].all_refs() for sid in mgr.order]
        restored, _ = mgr.restore()
        mgr.close()
        return refs, restored

    refs_sync, rest_sync = run(False)
    refs_async, rest_async = run(True)
    assert refs_sync == refs_async        # content-addressed => identical
    np.testing.assert_array_equal(rest_sync["['w']"], rest_async["['w']"])
    np.testing.assert_array_equal(rest_sync["['w']"], seq[-1]["w"])


def test_async_write_failure_is_invisible_and_rebases():
    rng = np.random.default_rng(22)
    store = ChunkStore()
    mgr = SnapshotManager(store, keep_last=5, async_mode=True,
                          delta_mode="ref")
    s0 = _state(rng)
    mgr.snapshot(s0, step=0, block=True)
    ok_sid = mgr.latest()

    real = store.put_delta
    calls = {"n": 0}

    def bomb(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("disk full")
        return real(*a, **kw)

    store.put_delta = bomb
    s1 = {"w": s0["w"] + 1.0, "m": s0["m"] + 1.0}   # >= 2 delta chunks
    mgr.snapshot(s1, step=1, block=False)
    with pytest.raises(OSError):
        mgr.wait()
    store.put_delta = real
    # the half-written snapshot never registered
    assert mgr.latest() == ok_sid
    assert len(mgr.manifests) == 1
    # next snapshot re-bases (poisoned mirrors) and restores bit-exactly
    s2 = {"w": s1["w"] + 1.0, "m": s1["m"]}
    info = mgr.snapshot(s2, step=2, block=True)
    assert info.snapshot_id != ok_sid
    restored, _ = mgr.restore()
    np.testing.assert_array_equal(restored["['w']"], s2["w"])
    np.testing.assert_array_equal(restored["['m']"], s2["m"])
    mgr.close()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_writer_gc_pump_interleaving_never_tears_snapshot(seed):
    """Async writer commits, auto-GC sweeps, and a replica pump drains the
    outbox concurrently; a scrubber resolves the LATEST committed manifest
    the whole time.  Every committed snapshot must stay fully resolvable
    (never torn), and the final restore must be bit-exact."""
    rng = np.random.default_rng(seed)
    rs = ReplicaSet(ChunkStore(), [ChunkStore()])
    mgr = SnapshotManager(rs, keep_last=3, async_mode=True,
                          writer_depth=2, delta_mode="ref")
    state = _state(rng)
    stop = threading.Event()
    errors: list[BaseException] = []

    def pump_loop():
        while not stop.is_set():
            try:
                rs.pump()
                time.sleep(0.0005)
            except BaseException as e:     # noqa: BLE001 - recorded
                errors.append(e)
                return

    def scrub_loop():
        while not stop.is_set():
            time.sleep(0.0002)
            sid = mgr.latest()
            if sid is None:
                continue
            man = mgr.manifests.get(sid)
            if man is None:
                continue
            try:
                for ent in man.tensors.values():
                    rs.resolve_buffer(ent.refs)
            except BaseException as e:     # noqa: BLE001 - torn snapshot
                errors.append(e)
                return

    threads = [threading.Thread(target=pump_loop),
               threading.Thread(target=scrub_loop)]
    for t in threads:
        t.start()
    try:
        for step in range(12):
            idx = rng.integers(0, state["w"].size, 60)
            w = state["w"].copy()
            w[idx] += 1.0
            state = {"w": w, "m": state["m"]}
            mgr.snapshot(state, step=step, block=False)
        mgr.wait()
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
    assert not errors, errors
    restored, _ = mgr.restore()
    np.testing.assert_array_equal(restored["['w']"], state["w"])
    rs.flush()
    mgr.close()
