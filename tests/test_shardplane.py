"""Randomized scheduler-invariant harness for the sharded plane.

Drives thousands of random submit/join/leave/request/report/steal/
failover interleavings against ``ShardedScheduler`` and a single
``VolunteerScheduler`` oracle, and asserts the conservation invariants:

* **exactly-once** — every submitted unit completes exactly once (the
  drained completion log never repeats or misses a unit, including
  across a mid-run shard kill);
* **bounded replication** — no unit ever accumulates more than
  ``replication + max_extra_results`` results;
* **credit conservation** — total minted completion credit equals
  completed units (each unit's credit splits over its canonical
  results), plus exactly the MiB-credit granted by ``credit_transfer``;
* **oracle differential** — the sharded completion set is byte-identical
  to the single-scheduler reference (deterministic per-unit results make
  the canonical hash a function of the unit alone).

Everything is seeded: a failing interleaving replays bit-for-bit.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.shardplane import ShardedScheduler
from repro.core.sim import ChurnSim


def honest_hash(unit_id: int) -> str:
    return f"h{unit_id}"


class Harness:
    """Seeded random-op driver for any scheduler speaking the
    request_work/report/drain_completed interface."""

    def __init__(self, sched, clock: SimClock, seed: int, *,
                 n_units: int = 240, corrupt: float = 0.0,
                 churn: bool = True, kill_at_frac: float = 0.0,
                 plane_script=None, check_every: int = 64):
        self.sched = sched
        self.clock = clock
        self.rng = np.random.default_rng(seed)
        self.n_units = n_units
        self.corrupt = corrupt
        self.churn = churn
        # membership schedule: [(frac, verb)] applies each elastic verb
        # ("kill"/"add"/"split"/"rejoin") once that fraction of units
        # completed — guaranteed mid-run, whatever the op mix does.
        # kill_at_frac is the single-kill shorthand the older tests use.
        if plane_script is None:
            plane_script = ([(kill_at_frac, "kill")]
                            if kill_at_frac else [])
        self.plane_script = sorted(plane_script)
        self._script_pos = 0
        self.check_every = check_every
        self.submitted = 0
        self.alive: set[str] = set()
        self.next_vol = 0
        self.completions: list[tuple[int, str]] = []
        self.killed_shard = None
        self.killed_stack: list[int] = []
        self.verbs_applied: list[str] = []
        self.max_results_seen = 0

    def spawn(self, n: int = 1) -> None:
        for _ in range(n):
            wid = f"vol-{self.next_vol}"
            self.next_vol += 1
            self.sched.join(wid)
            self.alive.add(wid)

    def pick(self) -> str:
        return sorted(self.alive)[self.rng.integers(len(self.alive))]

    def _op(self) -> None:
        r = self.rng.random()
        if self.submitted < self.n_units and r < 0.25:
            for _ in range(int(self.rng.integers(1, 8))):
                if self.submitted >= self.n_units:
                    break
                self.sched.submit(self.submitted, {"i": self.submitted})
                self.submitted += 1
        elif r < 0.80:
            w = self.pick()
            unit = self.sched.request_work(w)
            if unit is not None and self.rng.random() < 0.9:
                h = honest_hash(unit.unit_id)
                if self.rng.random() < self.corrupt:
                    h = f"bad-{self.rng.integers(1 << 30)}"
                self.sched.report(w, unit.unit_id, h)
            # else: sit on the lease until it expires
        elif r < 0.86 and self.churn and len(self.alive) > 3:
            w = self.pick()
            self.sched.leave(w)
            self.alive.discard(w)
        elif r < 0.94:
            self.spawn(1)
        else:
            self.clock.advance(float(self.rng.integers(1, 120)))

    def _membership_verb(self, verb: str) -> None:
        s = self.sched
        if verb == "kill":
            alive = s.alive_shards()
            if len(alive) < 2:
                return
            victim = int(alive[self.rng.integers(len(alive))])
            s.fail_shard(victim)
            if self.killed_shard is None:
                self.killed_shard = victim
            self.killed_stack.append(victim)
        elif verb == "add":
            s.add_shard()
        elif verb == "split":
            alive = s.alive_shards()
            if len(alive) < 2:
                return
            hot = max(alive,
                      key=lambda i: (s.shards[i].open_backlog(), -i))
            owned = sum(1 for o in s._range_owner if o == hot)
            if owned < 2:
                return
            s.split_shard(hot)
        elif verb == "rejoin":
            if not self.killed_stack:
                return
            s.rejoin_shard(self.killed_stack.pop(0))
        else:
            raise ValueError(f"unknown membership verb {verb!r}")
        self.verbs_applied.append(verb)

    def _mid_run_checks(self) -> None:
        # bounded replication holds at every instant, not just at the end
        for _, h in self.completions:
            pass
        for uid, wu in list(self.sched.units.items()) \
                if hasattr(self.sched.units, "items") else []:
            n = len(wu.results)
            self.max_results_seen = max(self.max_results_seen, n)
            assert n <= wu.replication + wu.max_extra_results, \
                f"unit {uid} over-replicated: {n} results"

    def run(self, max_ops: int = 60_000) -> list[tuple[int, str]]:
        self.spawn(6)
        ops = stall = 0
        last_done = 0
        while self.submitted < self.n_units or not self.sched.done():
            ops += 1
            assert ops < max_ops, (
                f"harness did not converge: {self.sched.stats}")
            self._op()
            while (self._script_pos < len(self.plane_script)
                   and len(self.completions) >= self.plane_script[
                       self._script_pos][0] * self.n_units):
                verb = self.plane_script[self._script_pos][1]
                self._script_pos += 1
                self._membership_verb(verb)
            got = self.sched.drain_completed()
            self.completions.extend(got)
            if ops % self.check_every == 0:
                self._mid_run_checks()
            # anti-livelock: everyone backing off / stuck quorum — jump
            # the clock and add a fresh volunteer
            if len(self.completions) == last_done:
                stall += 1
                if stall > 400:
                    self.clock.advance(self.sched.backoff_max_s
                                       + self.sched.deadline_s + 1.0)
                    self.spawn(1)
                    stall = 0
            else:
                last_done = len(self.completions)
                stall = 0
        self.completions.extend(self.sched.drain_completed())
        return self.completions


def completion_bytes(completions) -> bytes:
    return json.dumps(sorted(completions)).encode()


def assert_invariants(h: Harness, expect_corrupt: bool) -> None:
    comps = h.completions
    uids = [uid for uid, _ in comps]
    assert len(uids) == len(set(uids)), "a unit completed more than once"
    assert set(uids) == set(range(h.n_units)), "lost or phantom units"
    # canonical hashes are the honest deterministic ones
    for uid, canon in comps:
        assert canon == honest_hash(uid)
    # bounded replication (final)
    for uid, wu in h.sched.units.items():
        assert len(wu.results) <= wu.replication + wu.max_extra_results
    # credit conservation: each completed unit mints exactly 1.0 credit,
    # split over its canonical results
    workers = h.sched.workers
    total = sum(i.credit for i in workers.values())
    assert total == pytest.approx(h.n_units, abs=1e-6), \
        f"minted credit {total} != completed units {h.n_units}"
    if not expect_corrupt:
        assert all(i.invalid == 0 for i in workers.values())


# ---------------------------------------------------------------------------
# oracle differential: sharded plane vs single scheduler, 3 seeds,
# including a mid-run shard kill + key-range reassignment
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_oracle_differential_with_shard_kill(seed):
    cfg = dict(replication=1, quorum=1, deadline_s=30.0,
               backoff_base_s=0.5, backoff_max_s=20.0)
    oclock = SimClock()
    oracle = VolunteerScheduler(clock=oclock, **cfg)
    oh = Harness(oracle, oclock, seed, n_units=240)
    ref = completion_bytes(oh.run())

    pclock = SimClock()
    plane = ShardedScheduler(shards=4, clock=pclock, watermark=2,
                             refill_batch=4, **cfg)
    ph = Harness(plane, pclock, seed, n_units=240, kill_at_frac=0.4)
    got = completion_bytes(ph.run())

    assert ph.killed_shard is not None, "shard kill never fired"
    assert plane.stats["shards_alive"] == 3
    assert got == ref, "sharded completion set diverged from the oracle"
    assert_invariants(ph, expect_corrupt=False)
    assert_invariants(oh, expect_corrupt=False)


@pytest.mark.parametrize("seed", [3, 4, 5])
def test_oracle_differential_quorum_corruption(seed):
    """replication 3 / quorum 2 with corrupt results: unique bad hashes
    can never meet quorum, so both systems converge to the honest set."""
    cfg = dict(replication=3, quorum=2, deadline_s=30.0,
               backoff_base_s=0.5, backoff_max_s=20.0)
    oclock = SimClock()
    oracle = VolunteerScheduler(clock=oclock, **cfg)
    oh = Harness(oracle, oclock, seed, n_units=80, corrupt=0.08)
    ref = completion_bytes(oh.run())

    pclock = SimClock()
    plane = ShardedScheduler(shards=3, clock=pclock, watermark=2,
                             refill_batch=4, **cfg)
    ph = Harness(plane, pclock, seed, n_units=80, corrupt=0.08,
                 kill_at_frac=0.4)
    got = completion_bytes(ph.run())

    assert got == ref
    assert_invariants(ph, expect_corrupt=True)


# ---------------------------------------------------------------------------
# oracle differential: elastic membership — randomized join/split/kill/
# rejoin schedules stay byte-identical to the single scheduler
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_oracle_differential_elastic_membership(seed):
    cfg = dict(replication=1, quorum=1, deadline_s=30.0,
               backoff_base_s=0.5, backoff_max_s=20.0)
    oclock = SimClock()
    oracle = VolunteerScheduler(clock=oclock, **cfg)
    oh = Harness(oracle, oclock, seed, n_units=240)
    ref = completion_bytes(oh.run())

    pclock = SimClock()
    plane = ShardedScheduler(shards=4, clock=pclock, watermark=2,
                             refill_batch=4, **cfg)
    script = [(0.10, "add"), (0.25, "split"), (0.40, "kill"),
              (0.55, "rejoin"), (0.70, "split")]
    ph = Harness(plane, pclock, seed, n_units=240, plane_script=script)
    got = completion_bytes(ph.run())

    # every verb fired (kill always finds >= 2 alive; rejoin follows it)
    assert ph.verbs_applied.count("kill") == 1
    assert ph.verbs_applied.count("add") == 1
    assert ph.verbs_applied.count("rejoin") == 1
    # the rejoined shard came back: the whole fleet of 5 is alive
    assert plane.stats["shards"] == 5
    assert plane.stats["shards_alive"] == 5
    assert got == ref, "elastic completion set diverged from the oracle"
    assert_invariants(ph, expect_corrupt=False)
    assert_invariants(oh, expect_corrupt=False)


# ---------------------------------------------------------------------------
# watermark refill + work stealing mechanics
# ---------------------------------------------------------------------------
def test_watermark_refill_batches():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, watermark=2, refill_batch=6,
                         steal=False)
    w = "vol-0"
    p.join(w)
    home = p.home_shard(w)
    # stock only the home shard: unit ids whose slot maps to `home`
    uids = [u for u in range(200)
            if p._range_owner[p.unit_slot(u)] == home][:20]
    for u in uids:
        p.submit(u, {})
    u0 = p.request_work(w)
    assert u0 is not None
    # one refill leased a whole batch: queue holds watermark+batch-1 after
    # the pop, and the shard shows that many outstanding leases
    assert p.plane_stats["refills"] == 1
    assert p.plane_stats["refill_units"] == 8   # watermark + refill_batch
    assert len(p._queues[w]) == 7
    # draining the queue costs no further refill until below watermark:
    # queue runs 7 -> 1 over six more pops with exactly zero refills...
    for _ in range(6):
        assert p.request_work(w) is not None
    assert p.plane_stats["refills"] == 1
    # ...and the next request finds it below watermark and refills once
    assert p.request_work(w) is not None
    assert p.plane_stats["refills"] == 2

def test_work_stealing_from_largest_backlog_tail():
    clock = SimClock()
    p = ShardedScheduler(shards=3, clock=clock, watermark=1, refill_batch=2)
    w = "vol-0"
    p.join(w)
    home = p.home_shard(w)
    others = [i for i in range(3) if i != home]
    # stock ONLY the two foreign shards, one with a much larger backlog
    big, small = others[0], others[1]
    big_units = [u for u in range(400)
                 if p._range_owner[p.unit_slot(u)] == big][:12]
    small_units = [u for u in range(400)
                   if p._range_owner[p.unit_slot(u)] == small][:3]
    for u in big_units + small_units:
        p.submit(u, {})
    unit = p.request_work(w)
    assert unit is not None
    assert p.plane_stats["steals"] == 1
    # stolen from the LARGEST backlog...
    assert p._unit_shard[unit.unit_id] == big
    # ...and from its tail (newest-first): the first stolen unit is the
    # last-submitted one of the big shard
    assert unit.unit_id == big_units[-1]


def test_steal_disabled_backs_off():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, steal=False)
    w = "vol-0"
    p.join(w)
    foreign = 1 - p.home_shard(w)
    uids = [u for u in range(100)
            if p._range_owner[p.unit_slot(u)] == foreign][:4]
    for u in uids:
        p.submit(u, {})
    assert p.request_work(w) is None            # home dry, stealing off
    assert p.stats["rejected_requests"] == 1
    assert not p.done()


# ---------------------------------------------------------------------------
# batched quorum
# ---------------------------------------------------------------------------
def test_quorum_validates_once_per_round_flush():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, replication=2, quorum=2)
    for w in ("a", "b"):
        p.join(w)
    p.submit(0, {})
    ua = p.request_work("a")
    ub = p.request_work("b")
    assert ua.unit_id == ub.unit_id == 0
    p.report("a", 0, "H")
    p.report("b", 0, "H")
    # nothing validated yet: reports are buffered for the round flush
    assert p.shards[p._unit_shard[0]].stats["completed"] == 0
    flushes0 = p.plane_stats["report_flushes"]
    assert p.done()                              # the flush point
    assert p.plane_stats["report_flushes"] == flushes0 + 1
    assert p.drain_completed() == [(0, "H")]
    # both canonical results arrived in ONE batch: credit split 50/50
    workers = p.workers
    assert workers["a"].credit == pytest.approx(0.5)
    assert workers["b"].credit == pytest.approx(0.5)


def test_report_buffer_cap_forces_flush():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, report_batch_max=4)
    p.join("w")
    for u in range(8):
        p.submit(u, {})
    held = []
    for _ in range(8):
        unit = p.request_work("w")
        assert unit is not None
        held.append(unit.unit_id)
    for i, uid in enumerate(held):
        p.report("w", uid, honest_hash(uid))
    # 8 buffered reports with a cap of 4: at least one forced flush
    assert p.plane_stats["report_flushes"] >= 1
    assert p.done()
    assert {u for u, _ in p.drain_completed()} == set(range(8))


# ---------------------------------------------------------------------------
# failover specifics
# ---------------------------------------------------------------------------
def test_fail_shard_migrates_results_and_credit():
    clock = SimClock()
    p = ShardedScheduler(shards=3, clock=clock, replication=2, quorum=2)
    # find a unit on shard 0 and two workers homed elsewhere
    uid = next(u for u in range(300)
               if p._range_owner[p.unit_slot(u)] == 0)
    p.submit(uid, {})
    workers = []
    i = 0
    while len(workers) < 2:
        w = f"w{i}"
        i += 1
        p.join(w)
        workers.append(w)
    a, b = workers
    # `a` reports its half of the quorum pre-kill (flushed), `b` holds
    ua = p.request_work(a)
    assert ua is not None and ua.unit_id == uid
    p.report(a, uid, "H")
    p.flush_reports()
    ub = p.request_work(b)
    assert ub is not None and ub.unit_id == uid
    info = p.fail_shard(0)
    assert info["reassigned_open"] == 1
    assert 0 not in p.alive_shards()
    # b's lease died with the shard; its result history survived, so the
    # re-dispatched unit still refuses a's double-report and completes
    # with one result from each worker
    target = p._unit_shard[uid]
    assert target != 0
    wu = p.units[uid]
    assert wu.results == {a: "H"}
    assert p.request_work(a) is None or p.units.get(uid).leases.get(a) is None
    guard = 0
    while not p.done():
        guard += 1
        assert guard < 200
        u2 = p.request_work(b)
        if u2 is not None:
            p.report(b, u2.unit_id, "H")
        else:
            clock.advance(50.0)
    assert p.drain_completed() == [(uid, "H")]
    merged = p.workers
    assert merged[a].credit == pytest.approx(0.5)
    assert merged[b].credit == pytest.approx(0.5)


def test_fail_shard_preserves_undrained_completions():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock)
    p.join("w")
    for u in range(10):
        p.submit(u, {})
    done = []
    guard = 0
    while not p.done():
        guard += 1
        assert guard < 500
        unit = p.request_work("w")
        if unit is None:
            clock.advance(50.0)
            continue
        p.report("w", unit.unit_id, honest_hash(unit.unit_id))
    p.flush_reports()
    # completions NOT yet drained; kill a shard, then drain
    p.fail_shard(0)
    done = p.drain_completed()
    assert {u for u, _ in done} == set(range(10))


def test_fail_shard_guards():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock)
    p.fail_shard(0)
    with pytest.raises(ValueError):
        p.fail_shard(0)                  # already down
    with pytest.raises(ValueError):
        p.fail_shard(1)                  # never kill the last shard


# ---------------------------------------------------------------------------
# elastic membership: add / split / rejoin
# ---------------------------------------------------------------------------
def test_add_shard_takes_fair_share_from_loaded_owners():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock)
    for u in range(64):
        p.submit(u, {})
    idx = p.add_shard()
    assert idx == 2 and p.stats["shards"] == 3
    owned = [sum(1 for o in p._range_owner if o == i) for i in range(3)]
    # n_slots=8 over 3 shards: the newcomer earns floor(8/3)=2 slots and
    # every unit resident on those slots moved with them
    assert owned[2] == 2
    assert sum(owned) == p.n_slots
    for uid, sidx in p._unit_shard.items():
        assert p._range_owner[p.unit_slot(uid)] == sidx
    # the new shard serves its slice: a full drain still completes all
    p.join("w")
    guard = 0
    while not p.done():
        guard += 1
        assert guard < 500
        wu = p.request_work("w")
        if wu is None:
            clock.advance(50.0)
            continue
        p.report("w", wu.unit_id, honest_hash(wu.unit_id))
    assert {u for u, _ in p.drain_completed()} == set(range(64))


def test_split_shard_halves_backlog_and_preserves_credit():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, replication=1, quorum=1)
    # stock ONLY shard 0: it becomes the hot shard the policy splits
    uids = [u for u in range(2000)
            if p._range_owner[p.unit_slot(u)] == 0][:60]
    for u in uids:
        p.submit(u, {})
    p.join("a")
    # `a` holds live leases on the shard about to split
    held = []
    for _ in range(4):
        wu = p.request_work("a")
        if wu is not None:
            held.append(wu.unit_id)
    before = p.shards[0].open_backlog()
    info = p.split_shard(0)
    assert info["split"] == 0 and info["target"] == 1
    assert info["slots"] >= 1
    # the handoff moved real open units and roughly halved the load
    after = [p.shards[i].open_backlog() for i in range(2)]
    assert after[0] < before and after[1] > 0
    assert abs(after[0] - after[1]) < before / 2
    # leases on moved units dropped; everything still completes once,
    # credit conserved at 1.0/unit
    for uid in held:
        p.report("a", uid, honest_hash(uid))
    guard = 0
    while not p.done():
        guard += 1
        assert guard < 1000
        wu = p.request_work("a")
        if wu is None:
            clock.advance(50.0)
            continue
        p.report("a", wu.unit_id, honest_hash(wu.unit_id))
    done = p.drain_completed()
    assert {u for u, _ in done} == set(uids)
    assert sum(i.credit for i in p.workers.values()) \
        == pytest.approx(len(uids))


def test_split_shard_guards():
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock)
    with pytest.raises(ValueError):
        p.split_shard(0, target=0)           # self-target
    p.fail_shard(1)
    with pytest.raises(ValueError):
        p.split_shard(1)                     # dead shard
    with pytest.raises(ValueError):
        p.split_shard(0)                     # no other alive shard


def test_rejoin_shard_returns_empty_and_earns_slots_back():
    clock = SimClock()
    p = ShardedScheduler(shards=3, clock=clock)
    for u in range(60):
        p.submit(u, {})
    p.fail_shard(0)
    assert sum(1 for o in p._range_owner if o == 0) == 0
    with pytest.raises(ValueError):
        p.rejoin_shard(1)                    # alive shard can't rejoin
    info = p.rejoin_shard(0)
    assert p.stats["shards_alive"] == 3
    # back with a fair share: floor(12/3) = 4 slots, and the resident
    # units of those slots migrated in with ownership
    assert sum(1 for o in p._range_owner if o == 0) == 4
    assert info["slots"] == 4
    assert all(not wu.completed for wu in p.shards[0].units.values())
    for uid, sidx in p._unit_shard.items():
        assert p._range_owner[p.unit_slot(uid)] == sidx
    # the full cycle still completes every unit exactly once
    p.join("w")
    guard = 0
    while not p.done():
        guard += 1
        assert guard < 500
        wu = p.request_work("w")
        if wu is None:
            clock.advance(50.0)
            continue
        p.report("w", wu.unit_id, honest_hash(wu.unit_id))
    assert {u for u, _ in p.drain_completed()} == set(range(60))


def test_rejoin_preserves_worker_ledger():
    """S1 regression: leave -> rejoin must not wipe minted credit."""
    clock = SimClock()
    s = VolunteerScheduler(replication=1, quorum=1, clock=clock)
    s.join("w")
    s.submit(0, {})
    wu = s.request_work("w")
    s.report("w", wu.unit_id, "H")
    assert s.workers["w"].credit == pytest.approx(1.0)
    s.leave("w")
    info = s.join("w")                       # the volunteer comes back
    assert info.alive
    assert info.credit == pytest.approx(1.0), \
        "rejoin wiped the worker's credit ledger"
    assert info.completed == 1
    assert info.backoff_k == 0 and info.backoff_until == 0.0


def test_refill_sizes_from_valid_entries_only():
    """S2 regression: expired queue entries must not shrink the refill."""
    clock = SimClock()
    p = ShardedScheduler(shards=2, clock=clock, watermark=2,
                         refill_batch=6, deadline_s=30.0, steal=False)
    w = "vol-0"
    p.join(w)
    home = p.home_shard(w)
    uids = [u for u in range(400)
            if p._range_owner[p.unit_slot(u)] == home][:30]
    for u in uids:
        p.submit(u, {})
    assert p.request_work(w) is not None     # queue: 7 leased entries
    assert p.plane_stats["refill_units"] == 8
    # churn: the home shard dies, its units migrate and the leases drop
    # — the 7 queued entries are now all invalid but still in the queue
    p.fail_shard(home)
    assert p.request_work(w) is not None
    # sizing from the raw queue would ask for 8 - 7 = 1 unit; pruning
    # first asks for the full watermark + batch again
    assert p.plane_stats["refill_units"] == 16, \
        "refill sized from stale queue entries"


def test_steal_prefers_low_request_rate_victim():
    """The steal policy weighs backlog by per-shard demand: a big backlog
    that is being drained fast by its own volunteers is a worse victim
    than a smaller idle one."""
    def build():
        clock = SimClock()
        p = ShardedScheduler(shards=3, clock=clock, watermark=1,
                             refill_batch=2)
        w = "vol-0"
        p.join(w)
        home = p.home_shard(w)
        others = [i for i in range(3) if i != home]
        big, small = others[0], others[1]
        big_units = [u for u in range(600)
                     if p._range_owner[p.unit_slot(u)] == big][:12]
        small_units = [u for u in range(600)
                       if p._range_owner[p.unit_slot(u)] == small][:8]
        for u in big_units + small_units:
            p.submit(u, {})
        return p, w, big, small

    # baseline: no demand anywhere -> raw backlog picks the big shard
    p, w, big, small = build()
    unit = p.request_work(w)
    assert p._unit_shard[unit.unit_id] == big
    # same backlogs, but the big shard is under heavy home demand:
    # 12/(1+5) = 2 effective < 8 idle -> steal from the small shard
    p, w, big, small = build()
    p._shard_req[big].inc(5)
    unit = p.request_work(w)
    assert p.plane_stats["steals"] == 1
    assert p._unit_shard[unit.unit_id] == small


# ---------------------------------------------------------------------------
# ChurnSim drives shard failover with the same seeded machinery
# ---------------------------------------------------------------------------
def test_churnsim_shard_kill_deterministic():
    def run(seed):
        clock = SimClock()
        plane = ShardedScheduler(shards=4, clock=clock)
        sim = ChurnSim(shards=plane, seed=seed)
        for u in range(40):
            plane.submit(u, {})
        for w in range(4):
            plane.join(f"v{w}")
        killed = sim.random_shard_kill()
        done = []
        guard = 0
        while not plane.done():
            guard += 1
            assert guard < 5000
            progressed = False
            for w in range(4):
                unit = plane.request_work(f"v{w}")
                if unit is not None:
                    progressed = True
                    plane.report(f"v{w}", unit.unit_id,
                                 honest_hash(unit.unit_id))
            if not progressed:
                clock.advance(100.0)
        done = plane.drain_completed()
        return killed, sorted(done)

    k1, d1 = run(7)
    k2, d2 = run(7)
    assert (k1, d1) == (k2, d2)                  # seed-deterministic
    assert {u for u, _ in d1} == set(range(40))
    k3, _ = run(11)
    sim_events_differ = (k3 != k1)
    # different seeds may pick a different victim; either way the sim
    # logged the kill as a fault-phase event
    assert k1 is not None


def test_churnsim_requires_a_target():
    with pytest.raises(ValueError):
        ChurnSim()
    clock = SimClock()
    plane = ShardedScheduler(shards=2, clock=clock)
    sim = ChurnSim(shards=plane, seed=0)
    with pytest.raises(RuntimeError):
        sim.pump()                               # no replicas attached


# ---------------------------------------------------------------------------
# trainer integration: the elastic loop speaks to the plane unchanged
# ---------------------------------------------------------------------------
def test_trainer_runs_on_sharded_plane():
    jax = pytest.importorskip("jax")
    from repro.core.elastic import SimWorker, VolunteerTrainer
    from repro.data.pipeline import DataConfig, TokenStream

    def grad_fn(params, batch):
        g = {k: np.ones_like(v) * (batch["tokens"].mean() / 1000.0)
             for k, v in params.items()}
        return np.float32(1.0), g

    def apply_fn(state, grads):
        return {k: v - 0.1 * grads[k] for k, v in state.items()}

    class _State(dict):
        @property
        def params(self):
            return self

    clock = SimClock()
    plane = ShardedScheduler(shards=3, clock=clock, watermark=2,
                             refill_batch=4, deadline_s=30.0)
    trainer = VolunteerTrainer(
        grad_fn=grad_fn, apply_fn=lambda s, g: _State(apply_fn(s, g)),
        state=_State({"w": np.zeros(4, np.float32)}),
        stream=TokenStream(DataConfig(64, 8, 2, seed=0)),
        micro_batches=6, scheduler=plane, seed=0)
    for i in range(5):
        trainer.add_worker(SimWorker(f"vol-{i}", fail_prob=0.1,
                                     rng=np.random.default_rng(i)))
    nxt = [5]

    def respawn(tr):
        tr.add_worker(SimWorker(f"vol-{nxt[0]}",
                                rng=np.random.default_rng(nxt[0])))
        nxt[0] += 1

    trainer.respawn = respawn
    stats = trainer.run(3)
    assert len(stats) == 3
    assert all(s.units == 6 for s in stats)
    assert plane.stats["completed"] == 18
    # the plane's refill machinery actually carried the rounds
    assert plane.stats["refills"] + plane.stats["steals"] > 0
