"""HLO/StableHLO analysis + cost model tests: the roofline machinery must
count loop trip counts correctly (validated against known graphs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_arch
from repro.launch import costmodel
from repro.launch.hlo_analysis import parse_collectives, stablehlo_flops
from repro.models.lm import RunConfig


def _flops_of(fn, *args):
    return stablehlo_flops(jax.jit(fn).lower(*args).as_text())


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    assert _flops_of(lambda a, b: a @ b, x, w) == 2 * 128 * 64 * 32


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)

    def scan_fn(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x, w):
        for i in range(9):
            x = x @ w[i]
        return x

    f_scan = _flops_of(scan_fn, x, w)
    f_unroll = _flops_of(unrolled, x, w)
    assert f_scan == f_unroll == 9 * 2 * 64 ** 3


def test_nested_scan_and_remat():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)

    def inner(c, wi):
        return jnp.tanh(c @ wi), None            # nonlinear: fwd is needed

    def fwd(x, w):
        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, w)      # 4 matmuls
            return c, None
        return jax.lax.scan(outer, x, None, length=3)[0]  # x3

    one_fwd = 12 * 2 * 32 ** 3
    assert _flops_of(fwd, x, w) == one_fwd

    def loss(x, w):
        return jax.checkpoint(lambda x, w: fwd(x, w),
                              policy=jax.checkpoint_policies
                              .nothing_saveable)(x, w).sum()

    # grad with full remat: fwd + recompute + bwd (dx and dw dots) ~ 4x fwd
    f = _flops_of(jax.grad(loss, argnums=(0, 1)), x, w)
    assert 3 * one_fwd <= f <= 5 * one_fwd


def test_batched_dot_general_flops():
    x = jax.ShapeDtypeStruct((8, 128, 64), jnp.float32)
    y = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    f = _flops_of(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), x, y)
    assert f == 2 * 8 * 128 * 64 * 32


# ---------------------------------------------------------------------------
# collective parser (synthetic post-SPMD HLO text)
# ---------------------------------------------------------------------------
SYNTHETIC_HLO = """\
HloModule jit_step

%cond1 (p: (s32[], f32[16,16])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body1 (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %ar = f32[16,16]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,8]<=[16], use_global_device_ids=true, to_apply=%add
  ROOT %t = (s32[], f32[16,16]) tuple(%iv2, %ar)
}

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %ag = f32[32,16]{1,0} all-gather(%a), channel_id=2, replica_groups={{0,1},{2,3}}, dimensions={0}
  %w = (s32[], f32[16,16]) while(%init), condition=%cond1, body=%body1
  ROOT %out = f32[16,16] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_trip_counts():
    stats = parse_collectives(SYNTHETIC_HLO, n_devices=16)
    # all-gather once: out 32*16*4 = 2048 B, g=2 -> wire (g-1)/g*S = 1024
    # all-reduce in while body x5: out 16*16*4=1024 B, g=8
    #   wire each = 2*(7/8)*1024 = 1792; x5 = 8960
    assert stats.op_counts["all-gather"] == 1
    assert stats.op_counts["all-reduce"] == 5
    assert stats.op_bytes["all-reduce"] == 5 * 1024
    assert np.isclose(stats.wire_bytes_per_device, 1024 + 8960)


def test_collective_parser_ignores_done_ops():
    txt = ("ENTRY %m (a: f32[8]) -> f32[8] {\n"
           "  %s = f32[8]{0} all-gather-start(%a), replica_groups={{0,1}}\n"
           "  %d = f32[8]{0} all-gather-done(%s)\n}")
    stats = parse_collectives(txt, 2)
    assert stats.op_counts.get("all-gather", 0) == 1


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_param_counts_match_known_sizes():
    expected = {"internlm2-20b": 19.9e9, "granite-3-2b": 2.6e9,
                "qwen2-1.5b": 1.5e9, "chameleon-34b": 34.3e9,
                "falcon-mamba-7b": 7.3e9, "deepseek-moe-16b": 16.9e9,
                "qwen3-moe-30b-a3b": 30.5e9, "hymba-1.5b": 1.7e9}
    for name, want in expected.items():
        got = get_arch(name).param_count()
        assert abs(got - want) / want < 0.05, (name, got)


def test_moe_active_params():
    c = get_arch("qwen3-moe-30b-a3b")
    active = c.active_param_count()
    assert 2.5e9 < active < 4e9          # the "A3B" in the name


def test_analytic_cost_kinds():
    cfg = get_arch("granite-3-2b")
    run = RunConfig()
    train = costmodel.analytic_cost(cfg, SHAPES["train_4k"], 256, run)
    dec = costmodel.analytic_cost(cfg, SHAPES["decode_32k"], 256, run)
    # train is 3x fwd (+remat 4/3); decode is 2*N*batch
    assert train.model_flops > 100 * dec.model_flops
    assert dec.hbm_bytes_per_device > 0
    # decode HBM is cache-dominated
    cache = costmodel._cache_bytes(cfg, SHAPES["decode_32k"], 256)
    assert cache / dec.hbm_bytes_per_device > 0.5
