"""MoE dispatch correctness: the grouped sort-based dispatch must equal the
dense per-token mixture when nothing is dropped, and degrade gracefully
under capacity pressure."""
import jax
import jax.nn as jnn
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, reduced
from repro.distributed.sharding import init_tree
from repro.moe.moe import moe_apply, moe_specs


def _dense_mixture_ref(p, x, cfg):
    logits = x @ p["router"]
    probs = jnn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(cfg.moe.n_experts):
        h = jnn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w = ((ei == e) * gv).sum(-1)
        ref = ref + w[..., None] * ye
    if "shared" in p:
        sh = p["shared"]
        ref = ref + jnn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"]) \
            @ sh["w_down"]
    return ref


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "qwen3-moe-30b-a3b"])
@pytest.mark.parametrize("bt", [(1, 4), (2, 8), (3, 17)])
def test_dispatch_matches_dense_mixture(arch, bt):
    cfg = reduced(get_arch(arch))
    p = init_tree(moe_specs(cfg), jax.random.key(0))
    b, t = bt
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (b, t, cfg.d_model)), jnp.float32)
    y, m = moe_apply(p, x, cfg, capacity_factor=8.0)  # no drops
    ref = _dense_mixture_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert float(m["moe_drop_frac"]) == 0.0


def test_capacity_drops_are_bounded_and_reported():
    cfg = reduced(get_arch("deepseek-moe-16b"))
    p = init_tree(moe_specs(cfg), jax.random.key(0))
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 64, cfg.d_model)), jnp.float32)
    y_tight, m_tight = moe_apply(p, x, cfg, capacity_factor=0.5)
    y_loose, m_loose = moe_apply(p, x, cfg, capacity_factor=8.0)
    assert float(m_tight["moe_drop_frac"]) > 0.0
    assert float(m_loose["moe_drop_frac"]) == 0.0
    # dropped tokens only lose part of their mixture; outputs stay finite
    assert bool(jnp.isfinite(y_tight).all())


def test_gates_are_differentiable():
    cfg = reduced(get_arch("qwen3-moe-30b-a3b"))
    p = init_tree(moe_specs(cfg), jax.random.key(3))
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        y, m = moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + m["moe_aux"]

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router receives gradient (through gates AND the aux loss)
    assert float(jnp.abs(g["router"]).sum()) > 0
