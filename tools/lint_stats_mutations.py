"""AST lint: reject bare ``<obj>.stats[...] += ...`` mutations.

PR 8 moved every component's counters onto the telemetry registry
(``core/telemetry.py``); the historical ``self.stats`` dicts are now
read-only :class:`~repro.core.telemetry.StatsView` objects, and mutation
goes through the typed handles (``self.metrics.<key>.inc()``).  A stray
``self.stats["x"] += 1`` would raise ``TypeError`` at runtime — but only
on the code path that executes it, so this lint rejects the pattern at
the AST level across the whole tree instead.

Flags any ``AugAssign`` or ``Assign`` whose target is a subscript of an
attribute (or bare name) called ``stats``, ``rstats``, ``plane_stats``
or ``tstats``, anywhere under the given paths, except inside
``telemetry.py`` itself (the one module allowed to own metric storage).

    python tools/lint_stats_mutations.py src
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

STATS_NAMES = frozenset({"stats", "rstats", "plane_stats", "tstats"})
ALLOWED_FILES = frozenset({"telemetry.py"})


def _stats_subscript(node: ast.expr) -> bool:
    """True for ``<expr>.stats[...]`` / ``stats[...]`` targets."""
    if not isinstance(node, ast.Subscript):
        return False
    base = node.value
    if isinstance(base, ast.Attribute):
        return base.attr in STATS_NAMES
    if isinstance(base, ast.Name):
        return base.id in STATS_NAMES
    return False


def lint_source(source: str, filename: str) -> list[str]:
    """-> ``file:line: message`` strings for every violation."""
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [f"{filename}:{e.lineno}: syntax error: {e.msg}"]
    out = []
    for node in ast.walk(tree):
        targets = []
        if isinstance(node, ast.AugAssign):
            targets = [node.target]
        elif isinstance(node, ast.Assign):
            targets = node.targets
        for t in targets:
            if _stats_subscript(t):
                snippet = ast.unparse(t)
                out.append(f"{filename}:{t.lineno}: mutation of read-only "
                           f"stats view `{snippet}` — use the typed "
                           f"metric: <component>.metrics.<key>.inc()")
    return out


def lint_paths(paths: list[Path]) -> list[str]:
    failures = []
    for root in paths:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if f.name in ALLOWED_FILES:
                continue
            failures.extend(lint_source(f.read_text(), str(f)))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    args = ap.parse_args(argv)
    failures = lint_paths([Path(p) for p in args.paths])
    for f in failures:
        print(f, file=sys.stderr)
    if failures:
        print(f"{len(failures)} stats-view mutation(s) found; counters "
              f"must go through the telemetry registry", file=sys.stderr)
        return 1
    print("no bare stats mutations found")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
