"""Quickstart: boot a V-BOINC capsule and train a small LM with volunteers.

Runs on CPU in ~a minute.  Demonstrates the paper's full Figure-1 flow:
server publishes a capsule -> client probes dependencies -> DepDisks attach
-> volunteer scheduler distributes validated work units -> differencing
snapshots guarantee recovery.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.server import Project, VBoincServer
from repro.core.snapshots import SnapshotManager
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw


def main():
    # ---- server side: publish the project ("VM image" + DepDisk manifest)
    store = ChunkStore()
    server = VBoincServer(store)
    spec = CapsuleSpec("granite-3-2b", "train_4k", RunConfig(remat="none"),
                       arch_override=reduced(get_arch("granite-3-2b")))
    server.publish(Project("quickstart-lm", spec,
                           dep_manifest={"disk": "optimizer-state"}))
    key = server.register_user("you")

    # ---- client side: fetch + verify the capsule
    fetched, missing, moved = server.fetch_capsule("quickstart-lm", set(), key)
    assert fetched.manifest_hash == spec.manifest_hash, "tampered capsule!"
    deps = server.probe_dependencies("quickstart-lm")
    print(f"capsule {fetched.manifest_hash[:12]} fetched "
          f"({moved} B moved); dependencies: {deps}")

    # ---- build the training job from the verified capsule spec
    cfg = fetched.arch
    run = fetched.run
    specs = api.state_specs(cfg)
    oc = adamw.AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=400)
    loss_fn = api.make_eval_loss(cfg, run)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def apply_fn(state, grads):
        p, o, _ = adamw.update(oc, grads, state.opt, state.params)
        return api.TrainState(p, o)

    state = api.TrainState(init_tree(specs.params, jax.random.key(0)),
                           init_tree(specs.opt, jax.random.key(0)))
    trainer = VolunteerTrainer(
        grad_fn=grad_fn, apply_fn=apply_fn, state=state,
        stream=TokenStream(DataConfig(cfg.vocab_size, 32, 8, seed=0)),
        micro_batches=2,
        scheduler=VolunteerScheduler(replication=2, quorum=2,
                                     deadline_s=10.0, clock=SimClock()),
        snapshots=SnapshotManager(store, keep_last=2), snapshot_every=5)

    # ---- volunteers: one of them lies, one is flaky
    trainer.add_worker(SimWorker("honest-0"))
    trainer.add_worker(SimWorker("honest-1"))
    trainer.add_worker(SimWorker("liar", corrupt_prob=0.2,
                                 rng=np.random.default_rng(1)))
    trainer.add_worker(SimWorker("flaky", fail_prob=0.1,
                                 rng=np.random.default_rng(2)))
    trainer.respawn = lambda tr: tr.add_worker(
        SimWorker(f"fresh-{len(tr.workers)}"))

    for s in range(30):
        st = trainer.round(s)
        if s % 5 == 0 or s == 29:
            print(f"step {st.step:3d} loss {st.loss:.4f} "
                  f"(invalid results caught: {st.invalid}, "
                  f"snapshot bytes: {st.snapshot_bytes})")
    print(f"\nscheduler: {trainer.sched.stats}")
    credit = {w.worker_id: round(w.credit, 1)
              for w in trainer.sched.workers.values()}
    print(f"credit: {credit}")
    assert trainer.history[-1].loss < trainer.history[0].loss - 0.5
    print("OK: loss decreased under a faulty volunteer fleet.")


if __name__ == "__main__":
    main()
