"""Batched serving inside a capsule: prefill once, decode with caches, with
capsule-level suspend/resume (the boinccmd-vs-controlvm split) mid-stream.

    PYTHONPATH=src python examples/serve_capsule.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.control import CapsuleRuntime, HostSupervisor
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig


def main():
    cfg = reduced(get_arch("falcon-mamba-7b"))     # attention-free decode
    run = RunConfig(remat="none", block_kv=64, ssm_chunk=16)
    params = init_tree(api.param_specs(cfg), jax.random.key(0))

    B, PROMPT, GEN = 4, 24, 12
    MAX = PROMPT + GEN
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, PROMPT)).astype(np.int32)

    runtime = CapsuleRuntime("serve-0")
    sup = HostSupervisor("host-0", runtime)
    sup.control_vm("startvm")

    prefill = jax.jit(api.make_prefill_step(cfg, MAX, run))
    decode = jax.jit(api.make_decode_step(cfg, run))

    logits, caches = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    for i in range(GEN - 1):
        if i == GEN // 2:                       # operator pauses the VM
            sup.control_vm("pause")
            assert not runtime.accepting_work
            sup.control_vm("unpause")           # ... and resumes; caches
            assert runtime.accepting_work       # live on, nothing is lost
        logits, caches = decode(params, caches,
                                {"tokens": tok,
                                 "index": jnp.int32(PROMPT + i)})
        tok = jnp.argmax(logits[:, 0, :cfg.vocab_size], -1) \
            .astype(jnp.int32)[:, None]
        out.append(np.asarray(tok))
    gen = np.concatenate(out, axis=1)
    print(f"served {B} requests, generated {gen.shape[1]} tokens each")
    print("first request tokens:", gen[0].tolist())
    print("runtime log:", runtime.log)


if __name__ == "__main__":
    main()
