"""DepDisk project switching: fine-tune TWO tasks off one shared base model.

The paper's §III-C claim: "when a user attaches to another BOINC project, a
new DepDisk need only be 'plugged in' … as opposed to downloading both a new
virtual machine image and DepDisk."  Here: the base disk holds the shared
pretrained params; each task's optimizer state lives in its own DepDisk.
Switching tasks = detach/attach; the base never moves again (chunk dedup
proves it: zero new bytes on re-snapshot).

    PYTHONPATH=src python examples/project_switch.py
"""
import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.chunkstore import ChunkStore
from repro.core.depdisk import DiskSet
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw


def main():
    cfg = reduced(get_arch("qwen2-1.5b"))
    run = RunConfig(remat="none", block_kv=16, ssm_chunk=8)
    specs = api.state_specs(cfg)
    params = init_tree(specs.params, jax.random.key(0))

    store = ChunkStore(chunk_bytes=1 << 14)
    disks = DiskSet(store, keep_last=2)
    base_info = disks.create_base(params)
    print(f"base disk (shared pretrained params): "
          f"{base_info.total_bytes / 1e6:.1f} MB, "
          f"{base_info.new_bytes / 1e6:.1f} MB stored")

    oc = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=200)
    loss_fn = api.make_eval_loss(cfg, run)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def train_task(task: str, params, opt, seed: int, steps: int = 6):
        stream = TokenStream(DataConfig(cfg.vocab_size, 32, 8, seed=seed))
        for i in range(steps):
            loss, g = grad_fn(params, stream.batch(i))
            params, opt, _ = adamw.update(oc, g, opt, params)
        return float(loss), params, opt

    # ---- task A: attach a fresh DepDisk ("fresh disk locally created")
    optA = init_tree(specs.opt, jax.random.key(1))
    disks.attach_dep("taskA")
    lossA, paramsA, optA = train_task("A", params, optA, seed=10)
    infoA = disks.snapshot_disk("taskA", {"params": paramsA, "opt": optA},
                                step=0)
    print(f"taskA trained (loss {lossA:.3f}); DepDisk snapshot "
          f"{infoA.new_bytes / 1e6:.1f} MB")

    # ---- switch project: only the DepDisk changes hands
    disks.swap_task("taskA", "taskB")
    optB = init_tree(specs.opt, jax.random.key(2))
    lossB, paramsB, optB = train_task("B", params, optB, seed=99)
    infoB = disks.snapshot_disk("taskB", {"params": paramsB, "opt": optB},
                                step=0)
    # base re-snapshot costs nothing: every chunk dedups
    base_again = disks.snapshot_disk("base", params, step=1)
    print(f"taskB trained (loss {lossB:.3f}); DepDisk snapshot "
          f"{infoB.new_bytes / 1e6:.1f} MB")
    print(f"base disk re-snapshot after switch: "
          f"{base_again.new_bytes} new bytes (all chunks deduped)")
    assert base_again.new_bytes == 0

    # ---- resume task A later from its DepDisk
    disks._attached["taskA"] = True
    got, _ = disks.restore_disk(
        "taskA", target_tree={"params": paramsA, "opt": optA})
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(got["params"])[0]),
        np.asarray(jax.tree.leaves(paramsA)[0]))
    print("taskA resumed bit-exactly from its DepDisk. OK")


if __name__ == "__main__":
    main()
