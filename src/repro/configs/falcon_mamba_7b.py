"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2410.05355; mamba1, attention-free",
))
