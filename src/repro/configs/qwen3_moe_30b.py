"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936,
    head_dim=128,  # hf:Qwen/Qwen3-30B-A3B uses head_dim=128 (!= d_model/n_heads)
    moe=MoEConfig(n_experts=128, n_shared_experts=0, top_k=8, d_ff_expert=768),
    source="hf:Qwen/Qwen3-30B-A3B; 128 experts top-8",
))
