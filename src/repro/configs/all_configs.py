"""Imports every bundled arch config so the registry is populated."""
from repro.configs.internlm2_20b import CONFIG as internlm2_20b  # noqa: F401
from repro.configs.granite_3_2b import CONFIG as granite_3_2b  # noqa: F401
from repro.configs.qwen2_1_5b import CONFIG as qwen2_1_5b  # noqa: F401
from repro.configs.minitron_8b import CONFIG as minitron_8b  # noqa: F401
from repro.configs.falcon_mamba_7b import CONFIG as falcon_mamba_7b  # noqa: F401
from repro.configs.deepseek_moe_16b import CONFIG as deepseek_moe_16b  # noqa: F401
from repro.configs.qwen3_moe_30b import CONFIG as qwen3_moe_30b  # noqa: F401
from repro.configs.chameleon_34b import CONFIG as chameleon_34b  # noqa: F401
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium  # noqa: F401
from repro.configs.hymba_1_5b import CONFIG as hymba_1_5b  # noqa: F401

ALL = [internlm2_20b, granite_3_2b, qwen2_1_5b, minitron_8b, falcon_mamba_7b, deepseek_moe_16b, qwen3_moe_30b, chameleon_34b, seamless_m4t_medium, hymba_1_5b]
