"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    source="arXiv:2407.10671; GQA kv=2, QKV bias",
))
