"""Architecture & shape configuration system.

Every assigned architecture is expressed as an ``ArchConfig``; every assigned
input shape as a ``ShapeConfig``.  The registry maps ``--arch <id>`` to a
config, mirroring how V-BOINC lets a volunteer select any BOINC project: the
capsule runtime is identical, only the payload (arch) changes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0     # always-on shared experts (DeepSeekMoE)
    top_k: int = 0
    d_ff_expert: int = 0          # per-expert hidden size
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> d_model // 16


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    enc_dec: bool = False         # seamless: n_layers encoder + n_layers decoder
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None        # None | "vq_image" | "audio_frames"
    # sliding-window attention (beyond-paper extra enabling long ctx on dense)
    window: int = 0                        # 0 -> full attention
    source: str = ""                       # provenance note

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.window > 0

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm.dt_rank or max(1, self.d_model // 16)

    def padded_vocab(self, multiple: int = 128) -> int:
        """Vocab padded for MXU alignment + mesh divisibility (DESIGN.md §4)."""
        return _round_up(self.vocab_size, multiple)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        v = self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family != "ssm":
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            per_layer += attn
        if self.family in ("ssm", "hybrid"):
            di, st = self.d_inner, self.ssm.d_state
            per_layer += d * 2 * di + di * self.ssm.d_conv \
                + di * (self.dt_rank + 2 * st) + self.dt_rank * di \
                + di * st + di + di * d
        if self.is_moe:
            fe = self.moe.d_ff_expert
            routed = self.moe.n_experts * 3 * d * fe
            shared = self.moe.n_shared_experts * 3 * d * fe
            per_layer += routed + shared + d * self.moe.n_experts
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        n_layers = self.n_layers * (2 if self.enc_dec else 1)
        if self.enc_dec:  # decoder cross-attention
            per_layer_dec_extra = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            return emb + n_layers * per_layer + self.n_layers * per_layer_dec_extra
        return emb + n_layers * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        fe = self.moe.d_ff_expert
        inactive = (self.moe.n_experts - self.moe.top_k) * 3 * d * fe
        return self.param_count() - self.n_layers * inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Cell disposition per DESIGN.md §4 (documented skips)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("long_500k skipped: pure full-attention arch "
                       "(O(L^2)); see DESIGN.md §4")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import side-effect registration of all bundled configs
    from repro.configs import all_configs  # noqa: F401


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            n_heads: int = 4, n_kv_heads: int = 0, d_ff: int = 128,
            vocab_size: int = 256) -> ArchConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    kv = n_kv_heads or max(1, n_heads // 2)
    kw = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=min(kv, n_heads), d_ff=d_ff, vocab_size=vocab_size,
        head_dim=d_model // n_heads,
    )
    if cfg.is_moe:
        kw["moe"] = MoEConfig(n_experts=4, n_shared_experts=cfg.moe.n_shared_experts and 1,
                              top_k=2, d_ff_expert=32)
        kw["d_ff"] = 0
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm"] = SSMConfig(d_state=4, d_conv=4, expand=2, dt_rank=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
