"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    frontend="vq_image",
    source="arXiv:2405.09818; early-fusion, VQ image tokens",
))
