"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408),
    source="arXiv:2401.06066; 2 shared + 64 routed top-6, fine-grained",
))
