"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2411.13676; parallel attn+mamba heads",
))
