"""Assigned architecture config (exact figures from the assignment table)."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, enc_dec=True,
    frontend="audio_frames",
    source="arXiv:2308.11596; enc-dec, multimodal",
))
