"""Deterministic, checkpointable synthetic data pipeline.

Work units are *index ranges* over an infinite deterministic token stream:
batch ``i`` is a pure function of ``(seed, i)``.  That determinism is what
makes the V-BOINC analogy work end-to-end — a failed volunteer's work unit
can be re-issued to any other worker and produce a bit-identical result
(quorum validation in core/scheduler.py relies on this), and the pipeline's
checkpoint is a single cursor integer carried in every snapshot.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: a noisy order-k Markov stream so the LM has
    # something learnable (loss decreases measurably within ~100 steps)
    markov_order: int = 1
    noise: float = 0.05


class TokenStream:
    """Infinite deterministic stream; ``batch(i)`` is random-access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed transition structure derived from the seed
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._mix = rng.integers(1, v, size=(cfg.markov_order,), dtype=np.int64)
        self._bias = int(rng.integers(0, v))

    def batch(self, index: int) -> dict:
        """Batch ``index`` -> {tokens (B,T+1) int32} (inputs + shifted labels)."""
        cfg = self.cfg
        v = cfg.vocab_size
        b, t = cfg.global_batch, cfg.seq_len
        rng = np.random.default_rng((cfg.seed, index))
        seqs = np.empty((b, t + 1), np.int64)
        seqs[:, :cfg.markov_order] = rng.integers(
            0, v, size=(b, cfg.markov_order))
        # vectorized Markov rollout with noise
        noise_mask = rng.random((b, t + 1)) < cfg.noise
        noise_tok = rng.integers(0, v, size=(b, t + 1))
        for j in range(cfg.markov_order, t + 1):
            nxt = (seqs[:, j - cfg.markov_order:j] @ self._mix
                   + self._bias) % v
            seqs[:, j] = np.where(noise_mask[:, j], noise_tok[:, j], nxt)
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}


@dataclass
class Cursor:
    """The pipeline's entire checkpointable state."""
    next_index: int = 0

    def advance(self) -> int:
        i = self.next_index
        self.next_index += 1
        return i

    def to_state(self) -> dict:
        return {"next_index": self.next_index}

    @classmethod
    def from_state(cls, state: dict) -> "Cursor":
        return cls(next_index=int(state["next_index"]))
