"""Mixture-of-Experts block (DeepSeekMoE / Qwen3-MoE style).

TPU-native dispatch, two design decisions:

* **Sort-based, FLOP-honest**: GShard one-hot dispatch einsums are memory-
  hungry and count fake FLOPs (the roofline's useful-ratio would lie).  We
  argsort (token, slot) pairs by expert id and *gather* into a dense
  (B, E, C, D) buffer — zero matmul FLOPs in routing, real FLOPs only in
  the expert matmuls.

* **Grouped per-DP-shard routing** (§Perf cell D): an earlier revision
  sorted the GLOBAL flattened token set, which forced GSPMD to all-gather
  every token across the data axis before routing (~36 s/step collective
  for deepseek train_4k).  Routing is independent per token, so we sort
  *within each batch row*: batch stays sharded on data, experts stay
  sharded on model (EP), and the only cross-device traffic left is the
  expert-combine partial-sum over the model axis.

Over-capacity tokens are dropped (capacity-factor semantics) per (row,
expert); the drop fraction is a reported metric.  Aux losses: switch-style
load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import TensorSpec, constrain
from repro.models import layers


def moe_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    e, fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
    out = {
        "router": TensorSpec((d, e), ("embed", None)),
        "w_gate": TensorSpec((e, d, fe), ("experts", "embed", "expert_ff")),
        "w_up": TensorSpec((e, d, fe), ("experts", "embed", "expert_ff")),
        "w_down": TensorSpec((e, fe, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.moe.n_shared_experts:
        out["shared"] = layers.mlp_specs(
            d, cfg.moe.n_shared_experts * fe)
    return out


def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, dict]:
    """x: (B, T, D) -> (y, metrics).  Differentiable through gates."""
    b, t, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    n_items = t * k                                    # per-row (token,slot)s

    logits = (x.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))                  # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)               # (B,T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                   # renormalize

    # ---- aux losses (global means; cheap scalars) ----
    me = probs.mean((0, 1))                                       # (E,)
    ce = jnp.zeros((b, e), jnp.float32).at[
        jnp.arange(b)[:, None], expert_ids.reshape(b, -1)].add(
        1.0 / (b * n_items)).sum(0) * 1.0
    aux = e * jnp.sum(me * ce)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- grouped (per batch row) sort-based dispatch ----
    flat_expert = expert_ids.reshape(b, n_items)                  # (B,I)
    flat_gate = gate_vals.reshape(b, n_items)
    flat_token = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[:, None], (t, k)).reshape(1, n_items)
    flat_token = jnp.broadcast_to(flat_token, (b, n_items))
    order = jnp.argsort(flat_expert, axis=-1)                     # stable
    take = lambda a: jnp.take_along_axis(a, order, axis=-1)       # noqa: E731
    sorted_expert = take(flat_expert)
    sorted_token = take(flat_token)
    sorted_gate = take(flat_gate)

    cap = max(int(capacity_factor * n_items / e), 1)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    counts = jnp.zeros((b, e), jnp.int32).at[
        jnp.broadcast_to(rows, (b, n_items)), sorted_expert].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros((b, 1), counts.dtype), jnp.cumsum(counts, -1)[:, :-1]],
        axis=-1)                                                  # (B,E)
    rank = jnp.arange(n_items) - jnp.take_along_axis(
        offsets, sorted_expert, axis=-1)                          # (B,I)
    keep = rank < cap                                             # capacity

    # gather tokens into the (B, E, C, D) expert buffer (local per shard)
    slot_pos = offsets[:, :, None] + jnp.arange(cap)[None, None]  # (B,E,C)
    slot_valid = jnp.arange(cap)[None, None] < \
        jnp.minimum(counts, cap)[:, :, None]
    slot_pos = jnp.clip(slot_pos, 0, n_items - 1)
    tok_for_slot = jnp.take_along_axis(
        sorted_token.reshape(b, 1, n_items),
        slot_pos.reshape(b, 1, e * cap), axis=-1).reshape(b, e, cap)
    xin = jnp.take_along_axis(
        x[:, None], tok_for_slot[..., None].astype(jnp.int32), axis=2) \
        * slot_valid[..., None].astype(x.dtype)                   # (B,E,C,D)
    xin = constrain(xin, ("act_batch", "experts", None, None))

    # expert MLPs — the only matmul FLOPs in this block (E sharded: EP)
    dt = x.dtype
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin,
                               p["w_gate"].astype(dt))) \
        * jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(dt))
    yexp = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))

    # combine: scatter-add back per row; partial sums over expert shards
    # become ONE model-axis all-reduce of (B_local, T, D)
    out_idx = jnp.where(keep, sorted_token, t)                    # drop -> bin T
    gate_w = jnp.where(keep, sorted_gate, 0.0)
    item_slot = sorted_expert * cap + jnp.clip(rank, 0, cap - 1)  # (B,I)
    item_y = jnp.take_along_axis(
        yexp.reshape(b, e * cap, d), item_slot[..., None], axis=1)  # (B,I,D)
    y = jnp.zeros((b, t + 1, d), item_y.dtype).at[
        jnp.broadcast_to(rows, (b, n_items)), out_idx].add(
        item_y * gate_w[..., None].astype(item_y.dtype))[:, :t]
    y = constrain(y, ("act_batch", "act_seq", "act_embed"))

    if "shared" in p:
        sh = p["shared"]
        y = y + layers.swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])

    metrics = {
        "moe_aux": aux,
        "moe_zloss": zloss,
        "moe_drop_frac": 1.0 - keep.mean(),
    }
    return y, metrics
