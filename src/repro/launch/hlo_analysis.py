"""Roofline-term extraction from compiled XLA artifacts.

CPU-backend caveat discovered empirically (see EXPERIMENTS.md §Dry-run):
``compiled.cost_analysis()`` counts ``while`` bodies ONCE, so any scan-based
model (all of ours) is undercounted by ~n_layers.  We therefore derive the
terms structurally, multiplying by loop trip counts:

* FLOPs — parsed from the *lowered* StableHLO (operand types are explicit
  there), summing ``dot_general`` costs with a multiplier stack maintained
  across ``stablehlo.while`` regions (trip count = the loop bound constant in
  the cond region) and ``func.call`` edges.  Pre-SPMD global FLOPs; divided
  by device count for the per-device term.  Rematerialization duplicates are
  visible at this level, so MODEL_FLOPS/HLO_FLOPs honestly exposes remat and
  dispatch waste.

* Collective bytes — parsed from the *compiled* (post-SPMD) HLO, where
  GSPMD's collectives exist.  Operands are printed untyped, so byte counts
  come from output shapes with ring-model wire costs:

      all-reduce          2*(g-1)/g * S_out
      all-gather          (g-1)/g   * S_out
      reduce-scatter      (g-1)     * S_out
      all-to-all          (g-1)/g   * S_out
      collective-permute  S_out

  (g = group size from replica_groups) multiplied through the while-loop
  call graph exactly like FLOPs.

* HBM bytes — analytic inventory (launch/costmodel.py): cost_analysis bytes
  suffer the same trip-count issue and CPU fusion differs from TPU anyway.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

from repro.launch import mesh as mesh_mod

# ---------------------------------------------------------------------------
# StableHLO FLOPs (trip-count aware)
# ---------------------------------------------------------------------------
_FUNC_RE = re.compile(r"func\.func\s+(?:public|private)?\s*@([\w.$-]+)")
_CALL_RE = re.compile(r"func\.call\s+@([\w.$-]+)")
_DENSE_INT_RE = re.compile(r"dense<(\d+)>")
_DOT_RE = re.compile(
    r"stablehlo\.dot_general\s.*?"
    r"(?:contracting_dims\s*=\s*\[([\d, ]*)\]\s*x\s*\[[\d, ]*\])"
    r".*?:\s*\(tensor<([^>]+)>,\s*tensor<([^>]+)>\)\s*->\s*tensor<([^>]+)>")
_CONV_RE = re.compile(
    r"stablehlo\.convolution.*?:\s*\(tensor<([^>]+)>,\s*tensor<([^>]+)>\)"
    r"\s*->\s*tensor<([^>]+)>")


def _tensor_dims(t: str) -> list[int]:
    return [int(d) for d in t.split("x")[:-1] if d.isdigit()]


def _tensor_numel(t: str) -> int:
    n = 1
    for d in _tensor_dims(t):
        n *= d
    return n


def _dot_flops(line: str) -> float:
    m = _DOT_RE.search(line)
    if not m:
        return 0.0
    cdims = [int(x) for x in m.group(1).replace(" ", "").split(",") if x]
    lhs = _tensor_dims(m.group(2))
    out_numel = _tensor_numel(m.group(4))
    k = 1
    for c in cdims:
        if c < len(lhs):
            k *= lhs[c]
    return 2.0 * out_numel * k


@dataclass
class _Fn:
    flops: float = 0.0
    calls: dict = field(default_factory=dict)   # callee -> multiplier


def stablehlo_flops(text: str) -> float:
    """Global matmul FLOPs of a lowered module, while-trip aware."""
    fns: dict[str, _Fn] = {}
    cur: Optional[_Fn] = None
    # stack entries: ("while_pending", trip) | ("scale", factor) | ("brace",)
    scale = 1.0
    stack: list[tuple] = []
    pending_trip: Optional[list] = None   # collecting cond-region constants

    for raw in text.splitlines():
        line = raw.strip()
        fm = _FUNC_RE.search(line)
        if fm and "func.func" in line:
            cur = fns.setdefault(fm.group(1), _Fn())
            scale, stack, pending_trip = 1.0, [], None
        if cur is None:
            continue
        if "stablehlo.while" in line:
            # next `cond { ... }` region holds the bound
            pending_trip = []
        if pending_trip is not None:
            for c in _DENSE_INT_RE.findall(line):
                pending_trip.append(int(c))
        opens = raw.count("{") - raw.count("}")
        if line.startswith("} do {") or line == "do {" or line.endswith("} do {"):
            trip = max(pending_trip) if pending_trip else 1
            pending_trip = None
            stack.append(("scale", trip))
            scale *= max(trip, 1)
            continue
        if "stablehlo.dot_general" in line:
            cur.flops += scale * _dot_flops(line)
        elif "func.call" in line:
            cm = _CALL_RE.search(line)
            if cm:
                cur.calls[cm.group(1)] = cur.calls.get(cm.group(1), 0) + scale
        # brace tracking (after content processing)
        for _ in range(max(opens, 0)):
            stack.append(("brace",))
        for _ in range(max(-opens, 0)):
            if stack:
                kind = stack.pop()
                if kind[0] == "scale":
                    scale /= max(kind[1], 1)

    # resolve call graph from main
    memo: dict[str, float] = {}

    def total(name: str, depth=0) -> float:
        if name in memo or depth > 50:
            return memo.get(name, 0.0)
        fn = fns.get(name)
        if fn is None:
            return 0.0
        t = fn.flops + sum(mult * total(callee, depth + 1)
                           for callee, mult in fn.calls.items())
        memo[name] = t
        return t

    if "main" in fns:
        return total("main")
    return sum(total(n) for n in fns)


# ---------------------------------------------------------------------------
# Compiled-HLO collectives (trip-count aware)
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s+\(.*->")
_COLL_OP_RE = re.compile(
    r"=\s*(.*?)\s(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-_]+),\s*body=%?([\w.\-_]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes_list(segment: str) -> int:
    n = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        for d in dims.split(","):
            if d:
                numel *= int(d)
        n += numel * _DTYPE_BYTES[dt]
    return n


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


@dataclass
class CollectiveStats:
    op_counts: dict = field(default_factory=dict)    # static op -> dynamic count
    op_bytes: dict = field(default_factory=dict)     # output bytes (dynamic)
    wire_bytes_per_device: float = 0.0

    @property
    def total_output_bytes(self) -> float:
        return sum(self.op_bytes.values())

    def seconds(self, link_bw: float = mesh_mod.ICI_BW_PER_LINK) -> float:
        return self.wire_bytes_per_device / link_bw


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    # 1) split into computations
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):  # computation header at col 0
            m = _COMP_RE.match(line.replace("ENTRY ", ""))
            if "ENTRY" in line:
                m = _COMP_RE.match(line[line.index("ENTRY") + 6:].strip())
                cur = "__entry__"
                comps[cur] = []
                continue
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                continue
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    if "__entry__" not in comps:  # fallback: treat whole text as one comp
        comps["__entry__"] = hlo_text.splitlines()

    # 2) per-computation collectives + while edges
    class CompInfo:
        def __init__(self):
            self.colls: list[tuple[str, int, int]] = []   # (op, bytes, g)
            self.whiles: list[tuple[str, str]] = []       # (cond, body)
    infos: dict[str, CompInfo] = {}
    for name, lines in comps.items():
        info = CompInfo()
        for line in lines:
            cm = _COLL_OP_RE.search(line)
            if cm:
                nbytes = _shape_bytes_list(cm.group(1))
                g = _group_size(line, n_devices)
                if nbytes and g > 1:
                    info.colls.append((cm.group(2), nbytes, g))
            wm = _WHILE_RE.search(line)
            if wm:
                info.whiles.append((wm.group(1), wm.group(2)))
        infos[name] = info

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    # 3) BFS multiplier propagation from entry
    mult: dict[str, float] = {"__entry__": 1.0}
    work = ["__entry__"]
    seen_edges = set()
    while work:
        name = work.pop()
        info = infos.get(name)
        if not info:
            continue
        for cond, body in info.whiles:
            t = trip_count(cond)
            key = (name, body)
            if key in seen_edges:
                continue
            seen_edges.add(key)
            mult[body] = mult.get(body, 0.0) + mult[name] * t
            work.append(body)

    stats = CollectiveStats()
    for name, info in infos.items():
        m = mult.get(name, 0.0)
        if m <= 0 or not info.colls:
            continue
        for op, nbytes, g in info.colls:
            stats.op_counts[op] = stats.op_counts.get(op, 0) + m
            stats.op_bytes[op] = stats.op_bytes.get(op, 0) + nbytes * m
            stats.wire_bytes_per_device += _WIRE_FACTOR[op](g) * nbytes * m
    return stats


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------
def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


@dataclass
class Roofline:
    flops_per_device: float      # HLO-derived (global/chips)
    hbm_bytes_per_device: float  # analytic inventory
    coll: CollectiveStats
    n_devices: int
    model_flops_per_device: float = 0.0   # 6*N*D / chips

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / mesh_mod.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / mesh_mod.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll.seconds()

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak sustained if the dominant term were the runtime:
        useful model FLOPs / (bound_s * peak)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops_per_device / (
            self.bound_s * mesh_mod.PEAK_FLOPS_BF16)

    def summary(self) -> dict:
        return {
            "hlo_flops_per_device": self.flops_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_output_bytes": self.coll.total_output_bytes,
            "collective_wire_bytes_per_device": self.coll.wire_bytes_per_device,
            "collective_op_counts": dict(self.coll.op_counts),
            "collective_op_bytes": dict(self.coll.op_bytes),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "roofline_fraction": self.roofline_fraction,
        }
