"""Build one (arch × shape × mesh) "cell": abstract inputs, shardings, step fn.

Used by the multi-pod dry-run, the roofline benchmarks and the launcher —
single source of truth so the compiled artifact they analyse is identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, shape_applicable
from repro.distributed.sharding import (ShardingRules, TensorSpec,
                                        abstract_tree, use_rules)
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim.adamw import AdamWConfig


@dataclass
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    mesh: Any
    rules: ShardingRules
    step: Callable            # jitted, donated
    abstract_args: tuple      # ShapeDtypeStructs to .lower(*args)
    kind: str


def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh,
               run: RunConfig = RunConfig(),
               rules_overrides: Optional[dict] = None) -> Cell:
    ok, why = shape_applicable(arch, shape)
    if not ok:
        raise ValueError(why)
    rules = ShardingRules(mesh)
    if shape.kind != "train":
        # inference has no optimizer state: FSDP param sharding would only
        # add per-step all-gathers (§Perf iter: 615 MB/token on granite
        # decode).  Keep params TP-sharded on the model axis, DP-replicated.
        rules.rules["embed"] = None
    if rules_overrides:
        rules.rules.update(rules_overrides)
    if run.logical_rules:
        rules.rules.update(run.logical_rules)

    def with_rules(fn):
        """Activate the resolver during tracing so ``constrain()`` calls in
        model code bind activation shardings to THIS mesh."""
        def wrapped(*args):
            with use_rules(rules):
                return fn(*args)
        return wrapped

    in_specs = api.input_specs(arch, shape)
    batch_sh = rules.tree_shardings(in_specs)
    batch_abs = abstract_tree(in_specs, rules)

    if shape.kind == "train":
        state_specs = api.state_specs(arch)
        state_sh = rules.tree_shardings(state_specs)
        state_abs = abstract_tree(state_specs, rules)
        fn = with_rules(api.make_train_step(arch, run, AdamWConfig()))
        step = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,))
        return Cell(arch, shape, mesh, rules, step,
                    (state_abs, batch_abs), "train")

    param_specs = api.param_specs(arch)
    param_sh = rules.tree_shardings(param_specs)
    param_abs = abstract_tree(param_specs, rules)

    if shape.kind == "prefill":
        fn = with_rules(api.make_prefill_step(arch, shape.seq_len, run))
        cache_sh = rules.tree_shardings(
            api.cache_specs(arch, shape.global_batch, shape.seq_len))
        step = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                       out_shardings=(None, cache_sh))
        return Cell(arch, shape, mesh, rules, step,
                    (param_abs, batch_abs), "prefill")

    # decode: one token against a cache of seq_len
    cache_specs = api.cache_specs(arch, shape.global_batch, shape.seq_len)
    cache_sh = rules.tree_shardings(cache_specs)
    cache_abs = abstract_tree(cache_specs, rules)
    fn = api.make_decode_step(arch, run)

    def decode(params, caches, batch):
        with use_rules(rules):
            return fn(params, caches, batch)

    step = jax.jit(decode, in_shardings=(param_sh, cache_sh, batch_sh),
                   out_shardings=(None, cache_sh), donate_argnums=(1,))
    return Cell(arch, shape, mesh, rules, step,
                (param_abs, cache_abs, batch_abs), "decode")


def concrete_batch(arch: ArchConfig, shape: ShapeConfig, seed: int = 0,
                   batch_override: Optional[int] = None) -> dict:
    """Concrete host-side inputs for smoke/bench runs (small shapes only)."""
    b = batch_override or shape.global_batch
    t = shape.seq_len
    rng = np.random.default_rng(seed)
    if shape.kind == "train":
        out = {"tokens": rng.integers(0, arch.vocab_size, (b, t), dtype=np.int64).astype(np.int32),
               "labels": rng.integers(0, arch.vocab_size, (b, t), dtype=np.int64).astype(np.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": rng.integers(0, arch.vocab_size, (b, t), dtype=np.int64).astype(np.int32)}
    else:
        out = {"tokens": rng.integers(0, arch.vocab_size, (b, 1), dtype=np.int64).astype(np.int32),
               "index": np.int32(t - 1)}
    if arch.enc_dec and shape.kind in ("train", "prefill"):
        out["frames"] = rng.standard_normal((b, t, arch.d_model)).astype(np.float32)
    return out
