import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax -----------------------------------------
import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402

from repro.configs.base import SHAPES, get_arch, list_archs, shape_applicable  # noqa: E402
from repro.launch import costmodel, hlo_analysis, mesh as mesh_mod  # noqa: E402
from repro.launch.cell import build_cell  # noqa: E402
from repro.models.lm import RunConfig  # noqa: E402

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers AND
compiles under the production meshes — sharding mismatches, compile-time OOM
or unsupported collectives surface here, with zero device allocation
(all inputs are ShapeDtypeStructs).

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single_pod multi_pod --out experiments/dryrun
"""


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             run: RunConfig, out_dir: Path, tag: str = "",
             window: int = 0) -> dict:
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "run": {"remat": run.remat,
                               "rules": run.logical_rules or {},
                               "window": window}}
    arch = get_arch(arch_name)
    if window:
        import dataclasses
        arch = dataclasses.replace(arch, window=window)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir:
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{arch_name}__{shape_name}__{mesh_name}.json") \
                .write_text(json.dumps(rec, indent=2))
        return rec
    mesh = mesh_mod.make_mesh_by_name(mesh_name)
    n_dev = int(mesh.devices.size)
    t0 = time.time()
    try:
        with mesh:
            cell = build_cell(arch, shape, mesh, run)
            lowered = cell.step.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = hlo_analysis.memory_dict(compiled)
        print(compiled.memory_analysis())
        hlo_flops = hlo_analysis.stablehlo_flops(lowered.as_text())
        coll = hlo_analysis.parse_collectives(compiled.as_text(), n_dev)
        cost = costmodel.analytic_cost(arch, shape, n_dev, run)
        roof = hlo_analysis.Roofline(
            flops_per_device=hlo_flops / n_dev,
            hbm_bytes_per_device=cost.hbm_bytes_per_device,
            coll=coll, n_devices=n_dev,
            model_flops_per_device=cost.model_flops_w_attn / n_dev)
        rec.update(
            status="ok", lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_devices=n_dev,
            memory_analysis=mem, roofline=roof.summary(),
            model_flops_global=cost.model_flops,
            cost_analysis={k: v for k, v in
                           hlo_analysis.cost_dict(compiled).items()
                           if isinstance(v, (int, float))
                           and not k.startswith(("utilization",
                                                 "bytes accessed"))},
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = out_dir / f"{arch_name}__{shape_name}__{mesh_name}{suffix}.json"
        path.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single_pod", "multi_pod"],
                    choices=["single_pod", "multi_pod", "host"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--override", nargs="*", default=[], metavar="AXIS=MESH",
                    help="sharding-rule overrides for perf iteration, e.g. "
                         "'embed=none' (no FSDP) 'act_seq=model' (SP); "
                         "'none' maps to replication")
    ap.add_argument("--tag", default="", help="suffix for output JSONs "
                    "(perf-iteration variants)")
    ap.add_argument("--fsdp-gather", action="store_true",
                    help="gather-then-compute FSDP weights (see RunConfig)")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window attention size: beyond-paper extra "
                         "that makes long_500k lowerable for dense archs "
                         "(non-faithful to the source configs; reported "
                         "separately)")
    args = ap.parse_args()

    archs = list_archs() if args.arch == ["all"] else args.arch
    shapes = list(SHAPES) if args.shape == ["all"] else args.shape
    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v.lower() in ("none", "") else \
            tuple(v.split(",")) if "," in v else v
    run = RunConfig(remat=args.remat,
                    logical_rules=overrides or None,
                    fsdp_gather_weights=args.fsdp_gather)
    out_dir = Path(args.out)

    results = []
    for a in archs:
        for s in shapes:
            for m in args.mesh:
                print(f"=== dry-run {a} × {s} × {m} "
                      f"{args.tag or ''} ===", flush=True)
                rec = run_cell(a, s, m, run, out_dir, tag=args.tag,
                               window=args.window)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['compute_s']:.3e}s"
                             f" memory={r['memory_s']:.3e}s"
                             f" collective={r['collective_s']:.3e}s"
                             f" (compile {rec['compile_s']}s)")
                elif status == "error":
                    extra = " " + rec["error"]
                print(f"--> {status}{extra}", flush=True)
                results.append(rec)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nTOTAL: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
