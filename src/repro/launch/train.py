"""V-BOINC training launcher.

End-to-end driver: boots a capsule for ``--arch``, attaches Base/Dep disks,
runs volunteer-scheduled data-parallel training with periodic differencing
snapshots, and survives worker failures / restarts.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --preset smoke --steps 50 --workers 4 --fail-prob 0.05 \
        --snapshot-every 10 --outdir /tmp/run1
    # crash it, then:
    ... --resume --steps 50       # continues bit-exactly from the snapshot

``--preset full`` keeps the assigned architecture (TPU-scale; use the
dry-run on CPU); ``--preset smoke``/``--preset 100m`` build reduced
same-family configs sized for this container.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core import telemetry as tlm
from repro.core.chunkstore import ChunkStore
from repro.core.elastic import SimWorker, VolunteerTrainer
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.snapshots import SnapshotManager
from repro.data.pipeline import DataConfig, TokenStream
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig
from repro.optim import adamw


def build_arch(name: str, preset: str):
    cfg = get_arch(name)
    if preset == "full":
        return cfg
    if preset == "smoke":
        return reduced(cfg)
    if preset == "100m":
        # ~100M-param same-family config (example application scale)
        return reduced(cfg, n_layers=6, d_model=512, n_heads=8,
                       n_kv_heads=4, d_ff=2048, vocab_size=32768)
    raise ValueError(preset)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8, help="per micro-batch")
    ap.add_argument("--micro", type=int, default=2,
                    help="work units per optimizer step")
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--corrupt-prob", type=float, default=0.0)
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--quorum", type=int, default=1)
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the scheduler plane by account-key range "
                         "across N VolunteerScheduler shards (watermark "
                         "refill + work stealing; dispatch stays O(1) as "
                         "the fleet grows)")
    ap.add_argument("--rebalance", action="store_true",
                    help="elastic shard policy: after each round, split "
                         "the hottest shard into the coldest when its "
                         "backlog runs 2x ahead (needs --shards > 1)")
    ap.add_argument("--watermark", type=int, default=2,
                    help="per-volunteer pending-queue low watermark "
                         "(sharded plane only)")
    ap.add_argument("--refill-batch", type=int, default=8,
                    help="leases pulled per watermark refill scan "
                         "(sharded plane only)")
    ap.add_argument("--snapshot-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8+error-feedback gradient compression (4x "
                         "smaller volunteer result uploads)")
    ap.add_argument("--uplink", action="store_true",
                    help="delta-aware upload path: volunteers stream "
                         "quantized gradient deltas through the server's "
                         "chunk store; only changed blocks move up")
    ap.add_argument("--edge-caches", type=int, default=0,
                    help="edge delta caches fronting the snapshot store; "
                         "restore_latest routes through their discovery "
                         "service instead of the primary")
    ap.add_argument("--edge-capacity", type=int, default=1 << 28,
                    help="per-cache capacity in bytes (LRU by closure)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="replicate snapshot chains to N peer stores "
                         "(async, bounded outbox); the run survives a "
                         "primary store loss")
    ap.add_argument("--async-writer", action="store_true",
                    help="zero-stall snapshots: the round pays only the "
                         "device probe + changed-tile transfer; hashing, "
                         "RLE, store writes and chain rebase run on a "
                         "background writer thread (per-round stall is "
                         "reported as snapshot_stall_ms; a half-written "
                         "snapshot is never visible)")
    ap.add_argument("--writer-depth", type=int, default=2,
                    help="bounded queue depth for --async-writer; when the "
                         "writer falls behind by this many snapshots the "
                         "trainer blocks (counted as backpressure_ms in "
                         "the writer stats, i.e. visible stall) instead of "
                         "queueing unboundedly")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="enable lifecycle tracing; writes events.jsonl "
                         "(flight recorder), metrics.prom (Prometheus "
                         "text exposition) and trace_summary.txt "
                         "(trace_reduce post-mortem) into DIR at exit")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--outdir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    if args.preset == "full":
        raise SystemExit("--preset full is TPU-scale; use "
                         "repro.launch.dryrun on this container")

    cfg = build_arch(args.arch, args.preset)
    run = RunConfig(remat="none", block_kv=min(args.seq, 512), ssm_chunk=64)
    specs = api.state_specs(cfg)
    oc = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                           total_steps=max(args.steps * 2, 100))
    loss_fn = api.make_eval_loss(cfg, run)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    def apply_fn(state, grads):
        p, o, _ = adamw.update(oc, grads, state.opt, state.params)
        return api.TrainState(p, o)

    stream = TokenStream(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                    seed=args.seed))
    # one shared clock for the scheduler AND the telemetry hub: with a
    # fixed seed the flight-recorder stream is byte-identical across runs
    clock = SimClock()
    tel_dir = Path(args.telemetry) if args.telemetry else None
    if tel_dir is not None:
        tel_dir.mkdir(parents=True, exist_ok=True)
        tlm.set_default(tlm.Telemetry(tracing=True, clock=clock))
    root = Path(args.outdir) if args.outdir else None
    store = ChunkStore(root / "store" if root else None)
    replicas = None
    if args.replicas > 0:
        from repro.core.replica import ReplicaSet
        peers = [ChunkStore(root / f"replica{i}" if root else None)
                 for i in range(args.replicas)]
        # the set IS the snapshot store: writes land on the primary and
        # fan out through the bounded outbox the trainer pumps per round
        store = replicas = ReplicaSet(store, peers)
    snaps = SnapshotManager(store, root=root / "snaps" if root else None,
                            keep_last=3, async_mode=args.async_writer,
                            writer_depth=args.writer_depth)
    if args.shards > 1:
        from repro.core.shardplane import ShardedScheduler
        sched = ShardedScheduler(shards=args.shards,
                                 replication=args.replication,
                                 quorum=args.quorum, deadline_s=30.0,
                                 watermark=args.watermark,
                                 refill_batch=args.refill_batch,
                                 clock=clock)
    else:
        sched = VolunteerScheduler(replication=args.replication,
                                   quorum=args.quorum, deadline_s=30.0,
                                   clock=clock)
    edge = None
    if args.edge_caches > 0:
        from repro.core.edge import EdgeCache, EdgeTier
        # read-only delta caches fronting the snapshot store: the
        # trainer's restore path drains from their discovery service, and
        # they earn scheduler transfer credit for the bytes they serve
        edge = EdgeTier(store,
                        [EdgeCache(f"edge-{i}",
                                   capacity_bytes=args.edge_capacity)
                         for i in range(args.edge_caches)],
                        scheduler=sched)
    state = api.TrainState(init_tree(specs.params, jax.random.key(args.seed)),
                           init_tree(specs.opt, jax.random.key(args.seed)))

    server = None
    if args.uplink:
        # the volunteer project server: results come back as delta refs
        # through its chunk store instead of bare hashes
        from repro.core.capsule import CapsuleSpec
        from repro.core.server import Project, VBoincServer
        server = VBoincServer(ChunkStore())
        spec = CapsuleSpec(args.arch, "train_4k", run, arch_override=cfg)
        server.publish(Project("train", spec, scheduler=sched))
        server.register_user("launcher")

    trainer = VolunteerTrainer(
        grad_fn=grad_fn, apply_fn=apply_fn, state=state, stream=stream,
        micro_batches=args.micro, scheduler=sched, snapshots=snaps,
        snapshot_every=args.snapshot_every, seed=args.seed,
        compress_grads=args.compress_grads,
        server=server, project="train" if server else None,
        uplink=args.uplink, replicas=replicas, edge=edge)

    start_step = 0
    if args.resume:
        if root is not None:
            # pick up on-disk manifests from the previous process; ordered
            # by (step, created), NOT filename — snapshot ids restart per
            # process, so a resumed run's newest snapshot can sort first
            snaps.load_existing()
        abstract = jax.eval_shape(
            lambda: api.TrainState(init_tree(specs.params, jax.random.key(0)),
                                   init_tree(specs.opt, jax.random.key(0))))
        start_step = trainer.restore_latest(abstract)
        print(f"resumed from snapshot at step {start_step}")

    next_id = [0]

    def spawn(n: int) -> None:
        for _ in range(n):
            w = next_id[0]
            next_id[0] += 1
            trainer.add_worker(SimWorker(
                f"vol-{w}", fail_prob=args.fail_prob,
                corrupt_prob=args.corrupt_prob,
                rng=np.random.default_rng((args.seed, w))))

    spawn(args.workers)
    # elastic membership: replacements keep arriving as volunteers churn
    trainer.respawn = lambda tr: spawn(1)

    t0 = time.time()
    rebalance_splits = 0
    for s in range(start_step, start_step + args.steps):
        alive = sum(w.alive for w in trainer.workers.values())
        if alive < args.workers:
            spawn(args.workers - alive)
        st = trainer.round(s)
        if args.rebalance and args.shards > 1:
            moved = sched.rebalance()
            if moved is not None:
                rebalance_splits += 1
                print(f"step {s:4d} rebalance: split shard "
                      f"{moved['split']} -> {moved['target']} "
                      f"({moved['slots']} slots, "
                      f"{moved['reassigned_open']} open units)")
        if s % args.log_every == 0:
            up = (f" up {st.uplink_moved}/{st.uplink_dense}"
                  if args.uplink else "")
            print(f"step {st.step:4d} loss {st.loss:.4f} "
                  f"units {st.units} reissued {st.reissued} "
                  f"dup {st.duplicates} invalid {st.invalid} "
                  f"snap_bytes {st.snapshot_bytes} "
                  f"stall_ms {st.snapshot_stall_ms:.1f}{up}")
    snaps.close()                    # drain pending background writes
    wall = time.time() - t0
    tokens = args.steps * args.micro * args.batch * args.seq
    summary = {
        "arch": cfg.name, "steps": args.steps, "wall_s": round(wall, 2),
        "tokens_per_s": round(tokens / wall, 1),
        "final_loss": trainer.history[-1].loss,
        "scheduler": dict(trainer.sched.stats),
        "store": dict(store.stats),
        "alive_workers": sum(w.alive for w in trainer.workers.values()),
        "snapshot_stall_ms": round(sum(
            h.snapshot_stall_ms for h in trainer.history), 2),
    }
    if args.shards > 1:
        summary["shard_plane"] = sched.shard_report()
        if args.rebalance:
            summary["rebalance_splits"] = rebalance_splits
    if args.async_writer:
        summary["snapshot_writer"] = {
            k: round(v, 2) if isinstance(v, float) else v
            for k, v in snaps.writer_stats.items()}
    if replicas is not None:
        replicas.flush()             # durability: drain the outbox on exit
        summary["replication"] = {**dict(replicas.rstats),
                                  **replicas.replication_report()}
    if edge is not None:
        summary["edge"] = {**{k: int(v) for k, v in dict(edge.stats).items()},
                           "caches": edge.describe()}
    if server is not None:
        log = server.uplinks.get("train")
        hist = trainer.history
        summary["uplink"] = {
            "bytes_in": log.bytes_in if log else 0,
            "bytes_dedup": log.bytes_dedup if log else 0,
            "accepted": log.accepted if log else 0,
            "rejected": log.rejected if log else 0,
            "dense_bytes": sum(h.uplink_dense for h in hist),
            "worker_credit": {w: round(i.credit, 3) for w, i in
                              trainer.sched.workers.items()},
        }
    if tel_dir is not None:
        tel = tlm.get_default()
        n_events = trainer.dump_flight_recorder(tel_dir / "events.jsonl")
        (tel_dir / "metrics.prom").write_text(tel.prometheus())
        report = tlm.trace_reduce(tel)
        (tel_dir / "trace_summary.txt").write_text(report.summary() + "\n")
        summary["telemetry"] = {
            "dir": str(tel_dir), "events": n_events,
            "reissues": report.reissues,
            "attribution_rate": round(report.attribution_rate, 4),
            "anomalies": report.anomaly_kinds(),
        }
    print(json.dumps(summary, indent=2))
    if root is not None:
        (root / "summary.json").write_text(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
