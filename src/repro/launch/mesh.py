"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module touches no jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; everything else sees the container's single CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s (~50 GB/s/link)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests and benchmarks."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_mesh_by_name(name: str):
    if name == "single_pod":
        return make_production_mesh(multi_pod=False)
    if name == "multi_pod":
        return make_production_mesh(multi_pod=True)
    if name == "host":
        return make_host_mesh()
    raise ValueError(f"unknown mesh {name!r}")


def chips(mesh) -> int:
    return mesh.devices.size
