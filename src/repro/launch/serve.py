"""V-BOINC serving launcher: batched prefill + decode inside a capsule.

Serves a reduced-config model on CPU: a request queue is batched, prefilled
once, then decoded token-by-token with the KV/SSM caches — the inference
twin of the training driver (the paper's 'run typical BOINC projects'
claim: the same capsule mechanism hosts a serving workload unchanged).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.distributed.sharding import init_tree
from repro.models import api
from repro.models.lm import RunConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = reduced(get_arch(args.arch))
    run = RunConfig(remat="none", block_kv=128, ssm_chunk=32)
    params = init_tree(api.param_specs(cfg), jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["frames"] = rng.standard_normal(
            (args.requests, args.prompt_len, cfg.d_model)).astype(np.float32)

    prefill = jax.jit(api.make_prefill_step(cfg, max_len, run))
    decode = jax.jit(api.make_decode_step(cfg, run))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    def sample(lg, key):
        lg = lg[..., :cfg.vocab_size]
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / args.temperature).astype(
            jnp.int32)

    key = jax.random.key(args.seed)
    tok = np.asarray(sample(logits, key))[:, None]
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, caches = decode(params, caches,
                                {"tokens": jnp.asarray(tok),
                                 "index": jnp.int32(args.prompt_len + i)})
        tok = np.asarray(sample(logits[:, 0], sub))[:, None]
        generated.append(tok)
    t_decode = time.time() - t0

    out_tokens = np.concatenate(generated, axis=1)
    tps = args.requests * (args.gen - 1) / max(t_decode, 1e-9)
    summary = {
        "arch": cfg.name, "requests": args.requests,
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "decode_tokens_per_s": round(tps, 1),
        "sample_output": out_tokens[0, :8].tolist(),
    }
    print(json.dumps(summary, indent=2))
    return summary


if __name__ == "__main__":
    main()
