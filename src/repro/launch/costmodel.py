"""Analytic cost model: MODEL_FLOPS and ideal HBM traffic per cell.

MODEL_FLOPS follows the assignment: 6*N*D for training (2*N*D for inference
kinds), N = active matmul params (MoE: shared + top_k routed only; input
embedding-table lookups excluded, tied embeddings counted once as the LM
head).  The causal-attention quadratic term is tracked separately and added
for the "useful flops" numerator so long-context cells aren't unfairly
penalized.

HBM bytes is an *ideal minimum traffic* inventory (params/optimizer/grads,
saved activations under the remat policy, KV-cache traffic, logits) — the
right denominator for a memory roofline: compiled code can only be worse.
Per-device figures assume the resolver's shardings.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.lm import RunConfig


def matmul_params(cfg: ArchConfig, active: bool = False) -> int:
    """Params participating in matmuls per token (excl. input embed gather)."""
    n = cfg.active_param_count() if active else cfg.param_count()
    vp = cfg.vocab_size * cfg.d_model
    if cfg.tie_embeddings:
        return n            # single table, used as the lm_head matmul
    return n - vp           # drop the input embedding gather table


def attention_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Score+output matmul FLOPs (fwd), causal-halved; 0 for attention-free."""
    if cfg.attention_free:
        return 0.0
    hd = cfg.resolved_head_dim
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        # one token attends to the whole cache
        per_layer = 2 * 2 * b * cfg.n_heads * t * hd
    else:
        per_layer = 2 * 2 * b * cfg.n_heads * t * t * hd * 0.5
        if cfg.window:
            per_layer = 2 * 2 * b * cfg.n_heads * t * min(cfg.window, t) * hd
    layers = cfg.n_layers * (2 if cfg.enc_dec else 1)
    if cfg.enc_dec:  # cross attention (decoder) q*t x kv*t
        layers += cfg.n_layers
    return per_layer * layers


@dataclass
class ModelCost:
    model_flops: float           # 6ND / 2ND (global)
    model_flops_w_attn: float    # + attention quadratic (fwd-scaled)
    hbm_bytes_per_device: float  # ideal traffic per device per step


def analytic_cost(cfg: ArchConfig, shape: ShapeConfig, n_devices: int,
                  run: RunConfig = RunConfig()) -> ModelCost:
    n_active = matmul_params(cfg, active=True)
    n_total = cfg.param_count()
    d_tokens = shape.tokens_per_step
    vp = cfg.padded_vocab()

    if shape.kind == "train":
        flops = 6.0 * n_active * d_tokens
        attn = 3.0 * attention_flops(cfg, shape)          # fwd+bwd
        if run.remat in ("full", "dots"):
            flops *= 4.0 / 3.0                            # recompute fwd
            attn *= 4.0 / 3.0
    else:
        flops = 2.0 * n_active * d_tokens
        attn = attention_flops(cfg, shape)

    # ---------- ideal HBM traffic ----------
    b, t = shape.global_batch, shape.seq_len
    d = cfg.d_model
    p_local = n_total / n_devices
    layers = cfg.n_layers * (2 if cfg.enc_dec else 1)
    # per-device token count under batch DP (batch may be replicated)
    dp = min(b, n_devices)
    tokens_local = d_tokens / dp

    if shape.kind == "train":
        # params bf16 read (fwd+bwd) + f32 master read/write + grads f32 r/w
        # + adam m,v read/write  ->  ~ (2+2)*2 + 4*2 + 4*2 + 8*2 = 40 B/param
        param_traffic = 40.0 * p_local
        act_c = 4.0 if run.remat == "none" else 2.5       # saved acts r/w
        act_traffic = layers * tokens_local * d * 2.0 * act_c
        logits_traffic = tokens_local * vp * 2.0 * 2.0
        hbm = param_traffic + act_traffic + logits_traffic
    elif shape.kind == "prefill":
        param_traffic = 2.0 * p_local
        act_traffic = layers * tokens_local * d * 2.0 * 2.0
        cache_local = _cache_bytes(cfg, shape, n_devices)
        hbm = param_traffic + act_traffic + cache_local   # write cache once
    else:  # decode
        n_active_local = matmul_params(cfg, active=True) / n_devices
        param_traffic = 2.0 * n_active_local
        cache_local = _cache_bytes(cfg, shape, n_devices)
        hbm = param_traffic + cache_local                 # read full cache
    return ModelCost(model_flops=flops,
                     model_flops_w_attn=flops + attn,
                     hbm_bytes_per_device=hbm)


def _cache_bytes(cfg: ArchConfig, shape: ShapeConfig, n_devices: int) -> float:
    """Per-device KV/SSM cache bytes (bf16 KV, f32 SSM state)."""
    b, t = shape.global_batch, shape.seq_len
    total = 0.0
    if cfg.family != "ssm":
        kv = cfg.n_layers * b * t * cfg.n_kv_heads * cfg.resolved_head_dim \
            * 2 * 2  # k+v, bf16
        if cfg.enc_dec:
            kv *= 1.5  # + cross-attention cache (enc len <= t)
        total += kv
    if cfg.family in ("ssm", "hybrid"):
        total += cfg.n_layers * b * cfg.d_inner * (cfg.ssm.d_state + 3) * 4.0
    # caches shard over batch (data) and length (model) when divisible
    return total / n_devices
