"""Jit'd wrapper for the selective-scan kernel: padding + mode dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def selective_scan(x: jax.Array, dt: jax.Array, bm: jax.Array, cm: jax.Array,
                   a: jax.Array, *, block_t: int = 32, block_di: int = 128,
                   mode: str = "interpret") -> jax.Array:
    """x, dt: (B,T,Di); bm, cm: (B,T,N); a: (Di,N) -> (B,T,Di)."""
    if mode == "ref":
        return ssm_scan_ref(x, dt, bm, cm, a)
    b, t, di = x.shape
    pt = (-t) % min(block_t, t)
    pd = (-di) % min(block_di, di)
    if pt or pd:
        # dt=0 on padded steps -> abar=1, bx=0: exact identity transitions
        x = jnp.pad(x, ((0, 0), (0, pt), (0, pd)))
        dt = jnp.pad(dt, ((0, 0), (0, pt), (0, pd)))
        bm = jnp.pad(bm, ((0, 0), (0, pt), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pt), (0, 0)))
        a = jnp.pad(a, ((0, pd), (0, 0)))
    y = ssm_scan(x, dt, bm, cm, a, block_t=block_t, block_di=block_di,
                 interpret=(mode == "interpret"))
    return y[:, :t, :di]
