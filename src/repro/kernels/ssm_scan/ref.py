"""Pure-jnp oracle for the selective-scan kernel: plain sequential loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x: jax.Array, dt: jax.Array, bm: jax.Array, cm: jax.Array,
                 a: jax.Array) -> jax.Array:
    """x, dt: (B,T,Di); bm, cm: (B,T,N); a: (Di,N) -> y (B,T,Di)."""
    b, t, di = x.shape
    n = bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        abar = jnp.exp(dtt[:, :, None] * a[None])          # (B,Di,N)
        h = abar * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          bm.swapaxes(0, 1).astype(jnp.float32),
          cm.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)
