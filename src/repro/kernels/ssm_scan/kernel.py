"""Pallas TPU selective-scan (Mamba1) kernel.

Grid (B, n_di, n_t) with the TIME dim innermost-sequential; the recurrent
state h (block_di, N) persists in VMEM scratch across time tiles.  Inside a
tile the scan runs in its associative log-depth form over (block_t, block_di,
N) VMEM arrays — discretization (dt·A exponentials, dt·B·x) is fused so the
(T, Di, N) tensors never exist in HBM (that materialization is the memory
hot-spot of naive Mamba; chunking bounds it to the tile).

VMEM budget at defaults (block_t=64, block_di=256, N=16):
    abar/bx (+scan temporaries ~2x): 4 * 64*256*16*4 B = 16 MiB? -> too big;
    defaults are therefore (block_t=32, block_di=128): 4*32*128*16*4 = 1 MiB.
Inputs per tile (x, dt: (block_t, block_di); B, C: (block_t, N)) are
negligible.  dims: block_di multiple of 128 lanes; N=16 rides the sublane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, h_ref, *,
                block_t: int):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (bt, bdi)
    dt = dt_ref[0].astype(jnp.float32)        # (bt, bdi)
    bm = b_ref[0].astype(jnp.float32)         # (bt, N)
    cm = c_ref[0].astype(jnp.float32)         # (bt, N)
    a = a_ref[...].astype(jnp.float32)        # (bdi, N)

    abar = jnp.exp(dt[:, :, None] * a[None])              # (bt, bdi, N)
    bx = (dt * x)[:, :, None] * bm[:, None, :]            # (bt, bdi, N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = lax.associative_scan(combine, (abar, bx), axis=0)
    hs = a_cum * h_ref[...][None] + b_cum                 # (bt, bdi, N)
    h_ref[...] = hs[-1]
    o_ref[0] = jnp.einsum("tdn,tn->td", hs, cm).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_di",
                                             "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, bm: jax.Array, cm: jax.Array,
             a: jax.Array, *, block_t: int = 32, block_di: int = 128,
             interpret: bool = False) -> jax.Array:
    """Selective scan core.

    x, dt: (B, T, Di); bm, cm: (B, T, N); a: (Di, N)  ->  y (B, T, Di)
    where h_t = exp(dt_t a) h_{t-1} + dt_t b_t x_t  and  y_t = c_t . h_t.
    T % block_t == 0, Di % block_di == 0 (ops wrapper pads Di; pads T with
    dt=0 -> abar=1, bx=0, exact).
    """
    b, t, di = x.shape
    n = bm.shape[-1]
    block_t = min(block_t, t)
    block_di = min(block_di, di)
    assert t % block_t == 0 and di % block_di == 0
    grid = (b, di // block_di, t // block_t)

    return pl.pallas_call(
        functools.partial(_ssm_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_di),
                         lambda b_, d, i: (b_, i, d)),
            pl.BlockSpec((1, block_t, block_di),
                         lambda b_, d, i: (b_, i, d)),
            pl.BlockSpec((1, block_t, n), lambda b_, d, i: (b_, i, 0)),
            pl.BlockSpec((1, block_t, n), lambda b_, d, i: (b_, i, 0)),
            pl.BlockSpec((block_di, n), lambda b_, d, i: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_di),
                               lambda b_, d, i: (b_, i, d)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_di, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, bm, cm, a)
