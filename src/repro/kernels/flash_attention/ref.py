"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B,H,T,hd); k,v: (B,K,S,hd).  Naive full-softmax reference."""
    b, h, t, hd = q.shape
    kh = k.shape[1]
    rep = h // kh
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        tq = jnp.arange(t)[:, None]
        ts = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(ts <= tq, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
