"""Pallas TPU flash-attention (forward) kernel.

Grid (B, H, nQ, nK) with the KV dim innermost-sequential; online-softmax
running max / denominator / accumulator live in VMEM scratch across the KV
sweep.  GQA needs no materialized head repeat: the K/V BlockSpec index maps
divide the query-head index by the group size, so each (b, h) program pulls
its group's KV tile straight from HBM.

Block shapes default to (128, head_dim) — MXU-aligned (multiples of 128 on
the matmul dims) and well inside VMEM:
    q(128, hd) + k(128, hd) + v(128, hd) + acc(128, hd) + scores(128, 128)
    ≈ 5 * 128*128*4 B ≈ 320 KiB  «  16 MiB VMEM.
Causal masking skips fully-masked KV tiles via ``pl.when`` (no FLOPs, no
VMEM traffic for the matmuls).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 n_k: int, s_valid: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: tile is live iff some k-pos <= some q-pos
    live = True
    if causal:
        live = kj * block_k <= qi * block_q + block_q - 1

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        rq = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        rk = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal:
            s = jnp.where(rk <= rq, s, NEG_INF)
        if s_valid % block_k:   # mask zero-padded KV tail (non-causal path)
            s = jnp.where(rk < s_valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "s_valid"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    s_valid: int = 0) -> jax.Array:
    """q: (B, H, T, hd);  k, v: (B, K, S, hd) with H % K == 0.

    Returns (B, H, T, hd).  T % block_q == 0 and S % block_k == 0 required
    (the ops wrapper pads and passes ``s_valid`` = original S).
    """
    b, h, t, hd = q.shape
    _, kh, s, _ = k.shape
    assert h % kh == 0, (h, kh)
    rep = h // kh
    block_q = min(block_q, t)
    block_k = min(block_k, s)
    assert t % block_q == 0 and s % block_k == 0
    n_q, n_k = t // block_q, s // block_k
    scale = 1.0 / math.sqrt(hd)
    s_valid = s_valid or s

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k, s_valid=s_valid)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, i, j, rep=rep: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
