"""Jit'd public wrapper: layout adaptation + padding + dispatch.

Model code carries (B, T, H, hd); the kernel wants (B, H, T, hd) with
block-aligned T/S.  ``attend`` pads, transposes, calls the kernel (interpret
mode on CPU) and restores layout.  On non-TPU backends without interpret, it
falls back to the jnp reference — one call site, three execution modes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True, block_q: int = 128, block_k: int = 128,
           mode: str = "interpret") -> jax.Array:
    """q: (B,T,H,hd); k,v: (B,S,K,hd) -> (B,T,H,hd).

    mode: "tpu" (compiled pallas) | "interpret" | "ref".
    """
    if mode == "ref":
        out = attention_ref(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal)
        return out.transpose(0, 2, 1, 3)
    t, s = q.shape[1], k.shape[1]
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, block_q)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_k)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_k)
    out = flash_attention(qt, kt, vt, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=(mode == "interpret"),
                          s_valid=s)
    return out[:, :, :t].transpose(0, 2, 1, 3)
