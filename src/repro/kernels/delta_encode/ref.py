"""Pure-numpy oracle for delta encode/apply (bit-level XOR semantics)."""
from __future__ import annotations

import numpy as np

TILE = 8 * 1024


def delta_encode_ref(old: np.ndarray, new: np.ndarray):
    o = np.asarray(old).reshape(-1).view(np.uint8)
    n = np.asarray(new).reshape(-1).view(np.uint8)
    pad = (-o.size) % (TILE * 4)
    o = np.pad(o, (0, pad))
    n = np.pad(n, (0, pad))
    d = (o ^ n).view(np.int32).reshape(-1, 8, 1024)
    changed = np.any(d != 0, axis=(1, 2)).astype(np.int32)
    return d, changed


def fused_records_ref(old: np.ndarray, new: np.ndarray):
    """Oracle for the fused probe+gather kernel: (bitmap, compacted tiles).

    Bit-for-bit what ``fused_delta_records`` emits — compacted changed
    tiles in ascending tile order — computed in one vectorized pass."""
    d, changed = delta_encode_ref(old, new)
    return changed, d[changed.astype(bool)]


def fused_tiles_ref(o32: np.ndarray, n32: np.ndarray):
    """Tile-level oracle for ``fused_delta_tiles``: inputs are already
    (nblk, 8, 1024) int32 views (the bucketed tree diff's concatenated
    per-leaf tiles)."""
    d = o32 ^ n32
    changed = np.any(d != 0, axis=(1, 2)).astype(np.int32)
    return changed, d[changed.astype(bool)]


def delta_apply_ref(old: np.ndarray, delta: np.ndarray) -> np.ndarray:
    o = np.asarray(old)
    ob = o.reshape(-1).view(np.uint8)
    db = np.asarray(delta).reshape(-1).view(np.uint8)[:ob.size]
    return (ob ^ db).view(o.dtype).reshape(o.shape)
