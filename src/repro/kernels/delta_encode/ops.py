"""Jit'd wrapper + host-side compaction for differencing snapshots.

Three entry points:

* ``diff_blocks``/``patch_blocks`` — the original one-shot API: materialize
  the full delta, then compact on host (used by tests and small tensors).
* ``changed_blocks`` — the single-tensor snapshot/uplink hot path.  The
  default is the *fused* kernel: one ``pallas_call`` probes old vs new and
  DMA-compacts the changed tiles into the first k output slots, so a diff
  costs one launch and the only D2H traffic is the tiny bitmap plus the k
  changed tiles (paper §III-E: a differencing snapshot costs only the
  written-to blocks).  ``fused=False`` keeps the legacy two-launch
  probe-then-gather pipeline for comparison.
* ``tree_changed_blocks`` — the whole-pytree diff.  Leaves are grouped
  into size buckets (by power-of-two tile count) and each bucket's tile
  views are concatenated into ONE fused launch, so an optimizer tree with
  hundreds of small tensors diffs in O(size buckets) launches instead of
  O(leaves).
* ``probe_leaves`` — the SnapshotManager hot path: the same bucketed
  fused diff, but against the mirror slots ALONE — no host ``old`` images
  exist on the probing thread.  A missing or layout-mismatched slot seeds
  itself from the new tiles and reports its leaves for re-base, so the
  trainer-visible cost of a snapshot is exactly one probe plus the
  changed-tile transfer; chunking/hashing live on the writer thread.

A ``DeviceMirror`` keeps the previous state resident on device
(double-buffered: after each diff the *new* tiles become the mirror by
reference swap, not copy), eliminating the per-probe H→D re-upload of the
host mirror.  The numpy ``ref`` mode mirrors every kernel bit-for-bit
(used on hosts without a TPU runtime; the default when jax is on CPU).

``KERNEL_STATS`` counts launches and streamed bytes (ref-mode passes count
as one launch each) — ``benchmarks/roofline.py`` reads it to prove
launches-per-snapshot is O(buckets) and the probe runs at memory bandwidth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.kernels.delta_encode.kernel import (LANE, SUB, TILE, as_i32_tiles,
                                               changed_bitmap, delta_apply,
                                               delta_encode,
                                               fused_delta_tiles, gather_delta)
from repro.kernels.delta_encode.ref import (delta_apply_ref, delta_encode_ref,
                                            fused_records_ref, fused_tiles_ref)

TILE_BYTES = TILE * 4          # one (8, 1024) i32 tile = 32 KiB of state
_EMPTY_TILES = np.zeros((0, SUB, LANE), np.int32)

# dtypes the Pallas kernel can bitcast; everything else falls back to ref
KERNEL_DTYPES = ("int32", "float32", "bfloat16", "float16", "int16")

# leaves larger than this many tiles get their own launch; smaller ones are
# concatenated per power-of-two size bucket (256 tiles = 8 MiB of state)
MAX_BUCKET_TILES = 256

# launch/bandwidth accounting for benchmarks/roofline.py; a ref-mode pass
# over a (concatenated) tile view counts as one launch
KERNEL_STATS = {"launches": 0, "probe_bytes": 0, "d2h_bytes": 0}


def reset_kernel_stats() -> dict:
    prev = dict(KERNEL_STATS)
    for k in KERNEL_STATS:
        KERNEL_STATS[k] = 0
    return prev


def _count_launch(tile_bytes: int, d2h: int) -> None:
    KERNEL_STATS["launches"] += 1
    KERNEL_STATS["probe_bytes"] += 2 * tile_bytes   # streams old + new
    KERNEL_STATS["d2h_bytes"] += d2h


def _resolve_mode(mode: str) -> str:
    if mode != "auto":
        return mode
    import jax
    return "tpu" if jax.default_backend() == "tpu" else "ref"


class DeviceMirror:
    """Device-resident previous-state tiles, double-buffered per slot.

    A slot holds the (nblk, 8, 1024) i32 tile view of the last state seen
    for one leaf (or one size bucket's concatenation) plus a layout tag.
    ``swap`` stores the *new* tiles by reference — the diff's own input —
    so advancing the mirror after a snapshot costs zero copies and zero
    H→D transfers; the device-memory cost is one extra state image (the
    double buffer).  A slot may also pin the source leaf objects
    (``refs``): when the next round presents the *same immutable* arrays,
    the probe skips the launch outright — a frozen disk diffs for free."""

    def __init__(self):
        self._slots: Dict[Any, tuple] = {}  # key -> (layout, tiles, refs)

    def get(self, key, layout):
        ent = self._slots.get(key)
        if ent is None or ent[0] != layout:
            return None
        return ent[1]

    def refs(self, key, layout):
        ent = self._slots.get(key)
        if ent is None or ent[0] != layout:
            return None
        return ent[2]

    def swap(self, key, layout, tiles, refs=None) -> None:
        self._slots[key] = (layout, tiles, refs)

    def drop(self, key=None) -> None:
        if key is None:
            self._slots.clear()
        else:
            self._slots.pop(key, None)

    clear = drop

    def __len__(self) -> int:
        return len(self._slots)

    def nbytes(self) -> int:
        return sum(int(t.nbytes) for _, t, _ in self._slots.values())


def diff_blocks(old, new, *, mode: str = "interpret"):
    """-> (changed_tiles (k, 8, 1024) i32, bitmap (nblk,), orig_count)."""
    if mode == "ref":
        delta, changed = delta_encode_ref(old, new)
        n = np.asarray(old).size
    else:
        delta, changed, n = delta_encode(old, new,
                                         interpret=(mode == "interpret"))
        delta, changed = np.asarray(delta), np.asarray(changed)
    mask = changed.astype(bool)
    return delta[mask], changed, int(np.asarray(n))


def patch_blocks(old, changed_tiles, bitmap, *, mode: str = "interpret"):
    """Rebuild ``new`` from ``old`` + compacted changed tiles."""
    full = np.zeros((bitmap.size, 8, 1024), np.int32)
    full[bitmap.astype(bool)] = np.asarray(changed_tiles)
    if mode == "ref":
        return delta_apply_ref(old, full)
    out = delta_apply(old, full, interpret=(mode == "interpret"))
    return np.asarray(out)


def _check_dtypes(old, new, mode: str) -> str:
    """Validate the diff pair; returns the (possibly downgraded) mode.

    old/new dtype mismatch is always an error — silently bitcasting two
    different layouts would diff garbage.  A dtype the kernel cannot
    bitcast downgrades kernel modes to ``ref``."""
    old_dt = str(old.dtype if hasattr(old, "dtype")
                 else np.asarray(old).dtype)
    new_dt = str(new.dtype if hasattr(new, "dtype")
                 else np.asarray(new).dtype)
    if old_dt != new_dt:
        raise TypeError(f"changed_blocks: old dtype {old_dt} != new dtype "
                        f"{new_dt}; diff pairs must share a bit layout")
    if mode != "ref" and (old_dt not in KERNEL_DTYPES
                          or new_dt not in KERNEL_DTYPES):
        return "ref"
    return mode


def _fetch_compacted(bitmap: np.ndarray, tiles_dev, tile_bytes: int):
    """Host side of a fused launch: read the (tiny) bitmap, then transfer
    only the k compacted tiles (padded to the next power of two so the
    device slice sees O(log n) distinct shapes)."""
    k = int(bitmap.sum())
    if k == 0:
        _count_launch(tile_bytes, bitmap.nbytes)
        return np.zeros((0, SUB, LANE), np.int32)
    padded = min(1 << (k - 1).bit_length(), bitmap.size)
    tiles = np.asarray(tiles_dev[:padded])[:k]
    _count_launch(tile_bytes, bitmap.nbytes + padded * TILE_BYTES)
    return tiles


def changed_blocks(old, new, *, mode: str = "auto", emit: str = "tiles",
                   chunk_bytes: int = 0, fused: bool = True,
                   mirror: Optional[DeviceMirror] = None,
                   mirror_key=None):
    """Fused single-launch diff of one tensor.

    -> (changed_tiles (k, 8, 1024) i32 numpy, bitmap (nblk,) i32 numpy,
        nbytes).  ``mode``: "auto" (tpu kernel on TPU, numpy ref
    otherwise), "tpu", "interpret" (Pallas interpreter), or "ref".
    On the kernel paths only the bitmap and the k changed tiles are
    transferred to host.  ``fused=False`` uses the legacy two-launch
    probe-then-gather pipeline.

    ``mirror``/``mirror_key``: a ``DeviceMirror`` keeping the previous
    state's tiles resident on device.  When the slot matches, the probe is
    pure D2D (no H→D upload of ``old``) and the slot is swapped to the new
    tiles afterwards.  ``old`` must still be the previous *host* image —
    it feeds record compaction and the ref fallback.

    ``emit="records"`` is the *upload* mode: instead of raw tiles it
    returns ``(records, new_flat, nbytes)`` where ``records`` maps
    store-chunk index -> XOR bytes for exactly the chunks whose bytes
    changed — the per-chunk payloads ``ChunkStore.put_delta``/``ingest``
    expect — and ``new_flat`` is the updated uint8 host image (the
    caller's next mirror).  Requires ``chunk_bytes``.  Both the snapshot
    differencing path and the volunteer uplink encoder ride this mode.
    """
    host_old = old
    mode = _check_dtypes(old, new, _resolve_mode(mode))
    nbytes = int(old.nbytes) if hasattr(old, "nbytes") \
        else int(np.asarray(old).nbytes)
    if mode == "ref":
        bitmap, tiles = fused_records_ref(old, new)
        _count_launch(bitmap.size * TILE_BYTES, 0)
    elif fused:
        interpret = (mode == "interpret")
        import jax.numpy as jnp
        n32, _ = as_i32_tiles(jnp.asarray(new))
        layout = (n32.shape[0], nbytes)
        o32 = mirror.get(mirror_key, layout) if mirror is not None else None
        if o32 is None:
            import jax
            o32, _ = as_i32_tiles(jax.device_put(old))
        bm, tiles_dev = fused_delta_tiles(o32, n32, interpret=interpret)
        bitmap = np.asarray(bm)
        tiles = _fetch_compacted(bitmap, tiles_dev, n32.nbytes)
        if mirror is not None:
            mirror.swap(mirror_key, layout, n32)   # swap, not copy
    else:
        import jax
        import jax.numpy as jnp
        interpret = (mode == "interpret")
        old = jax.device_put(old)         # upload the mirror ONCE; both
        bm, _ = changed_bitmap(old, new, interpret=interpret)  # passes reuse
        bitmap = np.asarray(bm)           # tiny: one i32 per 32 KiB
        idx = np.flatnonzero(bitmap)
        k = idx.size
        tile_bytes = bitmap.size * TILE_BYTES
        _count_launch(tile_bytes, bitmap.nbytes)
        if k == 0:
            tiles = np.zeros((0, SUB, LANE), np.int32)
        else:
            # pad the gather index to the next power of two so gather_delta
            # sees O(log n) distinct shapes instead of recompiling per
            # changed-tile count
            padded = 1 << (k - 1).bit_length()
            idx = np.concatenate([idx,
                                  np.full(padded - k, idx[-1], idx.dtype)])
            tiles = np.asarray(gather_delta(old, new,
                                            jnp.asarray(idx, jnp.int32)))[:k]
            _count_launch(tile_bytes, padded * TILE_BYTES)
    if emit == "tiles":
        return tiles, bitmap, nbytes
    if emit != "records":
        raise ValueError(f"unknown emit mode {emit!r}")
    if chunk_bytes <= 0:
        raise ValueError("emit='records' requires chunk_bytes")
    records, new_flat = chunk_records(np.asarray(host_old), tiles, bitmap,
                                      nbytes, chunk_bytes)
    return records, new_flat, nbytes


def chunk_records(prev: np.ndarray, tiles: np.ndarray, bitmap: np.ndarray,
                  nbytes: int, chunk_bytes: int):
    """Compact changed tiles into store-ready per-chunk XOR records.

    -> (records: {chunk index -> XOR bytes}, new_flat uint8 image).
    Tiles (32 KiB probe granules) rarely align with store chunks; a chunk
    is recorded only when its bytes actually differ, so a tile flip that
    straddles two chunks but only dirties one emits one record.
    """
    old_flat = np.ascontiguousarray(prev).reshape(-1).view(np.uint8)
    if not bitmap.any():
        return {}, old_flat    # unchanged leaf: no records, no host copy
    new_flat = apply_tiles(old_flat.copy(), tiles, bitmap)
    # touched chunk set, vectorized: each changed tile covers byte range
    # [s, e) which spans chunks [s // cb, (e-1) // cb]
    ti = np.flatnonzero(bitmap)
    s = ti * TILE_BYTES
    e = np.minimum(s + TILE_BYTES, nbytes)
    valid = e > s
    s, e = s[valid], e[valid]
    records: dict[int, bytes] = {}
    if s.size == 0:
        return records, new_flat
    c0, c1 = s // chunk_bytes, (e - 1) // chunk_bytes
    width = int((c1 - c0).max()) + 1         # chunks per tile, usually <= 2
    cand = c0[:, None] + np.arange(width)[None, :]
    chunks = np.unique(cand[cand <= c1[:, None]])
    for ci in chunks:
        cs, ce = int(ci) * chunk_bytes, min((int(ci) + 1) * chunk_bytes,
                                            nbytes)
        xor = old_flat[cs:ce] ^ new_flat[cs:ce]
        if xor.any():
            records[int(ci)] = xor.tobytes()
    return records, new_flat


def _leaf_ntiles(nbytes: int) -> int:
    n_i32 = -(-nbytes // 4)
    return max(1, -(-n_i32 // TILE))


def _leaf_meta(leaf) -> tuple:
    """(nbytes, exact tile count, dtype str) of one leaf."""
    arr = leaf if hasattr(leaf, "nbytes") else np.asarray(leaf)
    nbytes = int(arr.nbytes)
    n_i32 = -(-nbytes // 4)
    return nbytes, -(-n_i32 // TILE), str(arr.dtype)


def _frozen(x) -> bool:
    """True when ``x`` cannot have been mutated in place: jax arrays are
    immutable; numpy only counts with the writeable flag off."""
    flags = getattr(x, "flags", None)
    return flags is None or not flags.writeable


def probe_leaves(news: Dict[str, Any], *, mode: str = "auto",
                 mirror: DeviceMirror,
                 bucketed: bool = True,
                 max_bucket_tiles: int = MAX_BUCKET_TILES):
    """The snapshot hot path's whole device-side cost: diff a dict of
    leaves against the resident mirror tiles, no ``old`` images needed.

    -> {key: (changed_tiles, bitmap, nbytes) | None}.  ``None`` means the
    mirror had no matching slot — first snapshot, a shape/dtype change, or
    a size bucket whose membership changed — and the caller must store
    those leaves as full base images; their new tiles are installed as the
    slot in the same pass, so the next round probes them.  Matched slots
    are diffed in one fused launch per size bucket and swapped to the new
    tiles (zero copies, zero H→D), so a whole-tree probe costs O(size
    buckets) launches and the only host traffic is the bitmaps plus the
    changed tiles.

    In ``ref`` mode the mirror slots hold numpy tile images and the probe
    is the vectorized oracle — bit-for-bit the kernel's results, same slot
    lifecycle (CI runs the identical code path minus the launch)."""
    mode = _resolve_mode(mode)
    buckets: Dict[int, list] = {}
    for key, leaf in news.items():
        nbytes, ntiles, dt = _leaf_meta(leaf)
        if mode != "ref" and dt not in KERNEL_DTYPES:
            bid = -2          # kernel tree, ref-only dtype: leaf-wise ref
        elif not bucketed or ntiles > max_bucket_tiles:
            bid = -3                             # standalone launches
        else:
            bid = (ntiles - 1).bit_length()      # pow2 size class
        buckets.setdefault(bid, []).append((key, nbytes, ntiles, dt))
    out: Dict[str, Any] = {}
    for bid, leaves in sorted(buckets.items()):
        if bid == -2:
            for key, nbytes, ntiles, dt in leaves:
                out[key] = _probe_slot(key, news[key],
                                       (nbytes, ntiles, dt), "ref", mirror)
        elif bid == -3:
            for key, nbytes, ntiles, dt in leaves:
                out[key] = _probe_slot(key, news[key],
                                       (nbytes, ntiles, dt), mode, mirror)
        else:
            out.update(_probe_bucket(bid, leaves, news, mode, mirror))
    return out


def _probe_slot(key, leaf, meta: tuple, mode: str, mirror: DeviceMirror):
    """Probe one standalone leaf against its own mirror slot (or seed it)."""
    nbytes, ntiles, dt = meta
    layout = ("leaf", nbytes, ntiles, dt)
    prev = mirror.refs(key, layout)
    if prev is not None and prev[0] is leaf and _frozen(leaf):
        # same immutable array as last round: unchanged by construction
        return _EMPTY_TILES, np.zeros(ntiles, np.int32), nbytes
    if mode == "ref":
        n32 = _ref_tiles(leaf)
        o32 = mirror.get(key, layout)
        mirror.swap(key, layout, n32, (leaf,))
        if o32 is None:
            return None
        if ntiles == 0:
            return _EMPTY_TILES, np.zeros(0, np.int32), nbytes
        bitmap, tiles = fused_tiles_ref(o32, n32)
        _count_launch(n32.nbytes, 0)
        return tiles, bitmap, nbytes
    import jax.numpy as jnp
    n32, _ = as_i32_tiles(jnp.asarray(leaf))
    o32 = mirror.get(key, layout)
    mirror.swap(key, layout, n32, (leaf,))
    if o32 is None:
        return None
    if ntiles == 0:
        return _EMPTY_TILES, np.zeros(0, np.int32), nbytes
    bm, tiles_dev = fused_delta_tiles(o32, n32,
                                      interpret=(mode == "interpret"))
    bitmap = np.asarray(bm)
    tiles = _fetch_compacted(bitmap, tiles_dev, int(n32.nbytes))
    return tiles, bitmap, nbytes


def _probe_bucket(bid: int, leaves: list, news: dict, mode: str,
                  mirror: DeviceMirror):
    """One fused launch over a size bucket's concatenated leaves, against
    the bucket's mirror slot.  A layout mismatch (bucket membership or any
    leaf's shape/dtype changed) re-seeds the slot and reports every leaf
    as un-probed (None) — the re-base amplification is confined to one
    bucket and only on layout changes."""
    layout = tuple((key, nb, nt, dt) for key, nb, nt, dt in leaves)
    skey = ("bucket", bid)
    if all(nt == 0 for _, _, nt, _ in leaves):   # all-empty bucket
        seeded = mirror.get(skey, layout) is not None
        mirror.swap(skey, layout, _EMPTY_TILES)
        return {key: ((_EMPTY_TILES, np.zeros(0, np.int32), nb)
                      if seeded else None)
                for key, nb, _, _ in leaves}
    leaf_objs = [news[key] for key, _, _, _ in leaves]
    prev = mirror.refs(skey, layout)
    if prev is not None and len(prev) == len(leaf_objs) and all(
            n is p and _frozen(n) for n, p in zip(leaf_objs, prev)):
        # every leaf is the same immutable array the slot was built from
        # (a frozen disk): unchanged by construction, no launch at all
        return {key: (_EMPTY_TILES, np.zeros(nt, np.int32), nb)
                for key, nb, nt, _ in leaves}
    if mode == "ref":
        parts = [_ref_tiles(x) for x in leaf_objs]
        n32 = parts[0] if len(parts) == 1 else np.concatenate(parts)
        o32 = mirror.get(skey, layout)
        mirror.swap(skey, layout, n32, tuple(leaf_objs))
        if o32 is None:
            return {key: None for key, _, _, _ in leaves}
        bitmap, tiles = fused_tiles_ref(o32, n32)
        _count_launch(n32.nbytes, 0)
    else:
        import jax.numpy as jnp
        parts = [as_i32_tiles(jnp.asarray(x))[0] for x in leaf_objs]
        n32 = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        o32 = mirror.get(skey, layout)
        mirror.swap(skey, layout, n32, tuple(leaf_objs))
        if o32 is None:
            return {key: None for key, _, _, _ in leaves}
        bm, tiles_dev = fused_delta_tiles(o32, n32,
                                          interpret=(mode == "interpret"))
        bitmap = np.asarray(bm)
        tiles = _fetch_compacted(bitmap, tiles_dev, int(n32.nbytes))
    out = {}
    off = pos = 0
    for key, nbytes, ntiles, _dt in leaves:
        bm_leaf = bitmap[off:off + ntiles]
        k = int(bm_leaf.sum())
        out[key] = (tiles[pos:pos + k], bm_leaf, nbytes)
        off += ntiles
        pos += k
    return out


def tree_changed_blocks(old_tree, new_tree, *, mode: str = "auto",
                        mirror: Optional[DeviceMirror] = None,
                        bucketed: bool = True,
                        max_bucket_tiles: int = MAX_BUCKET_TILES):
    """Bucketed diff over two pytrees.

    -> {keypath: (changed_tiles, bitmap, nbytes)}, keyed by
    ``jax.tree_util.keystr`` paths (the same keys snapshot manifests use).

    Leaves are grouped into size buckets (power-of-two tile count, capped
    at ``max_bucket_tiles``); each bucket's per-leaf i32 tile views are
    concatenated into ONE fused launch, so the whole tree diffs in
    O(size buckets) launches instead of one probe + gather per leaf.
    Leaves above the cap launch standalone (no concat copy of big params).
    With a ``DeviceMirror``, each bucket's concatenation (and each
    standalone leaf) is diffed against its device-resident previous image
    and the slot is swapped to the new tiles — zero H→D re-upload.
    ``bucketed=False`` keeps the legacy one-launch-per-leaf pipeline."""
    import jax
    olds = {jax.tree_util.keystr(p): leaf for p, leaf in
            jax.tree_util.tree_flatten_with_path(old_tree)[0]}
    news = {jax.tree_util.keystr(p): leaf for p, leaf in
            jax.tree_util.tree_flatten_with_path(new_tree)[0]}
    if olds.keys() != news.keys():
        raise ValueError("old/new trees have different structures")
    return diff_leaves(olds, news, mode=mode, mirror=mirror,
                       bucketed=bucketed, max_bucket_tiles=max_bucket_tiles)


def diff_leaves(olds: Dict[str, Any], news: Dict[str, Any], *,
                mode: str = "auto",
                mirror: Optional[DeviceMirror] = None,
                bucketed: bool = True,
                max_bucket_tiles: int = MAX_BUCKET_TILES):
    """Dict-level core of ``tree_changed_blocks``: diff ``news[k]`` against
    ``olds[k]`` per key, with size-bucketed fused launches.  The snapshot
    manager calls this directly with its host mirror as ``olds`` so leaf
    keys stay exactly the manifest keys."""
    if olds.keys() != news.keys():
        raise ValueError("old/new leaf sets differ")
    mode = _resolve_mode(mode)
    if not bucketed:
        return {k: changed_blocks(olds[k], news[k], mode=mode,
                                  mirror=mirror, mirror_key=k)
                for k in olds}

    # partition leaves: ref-only dtypes go leaf-wise through ref; the rest
    # bucket by power-of-two tile count
    out: Dict[str, tuple] = {}
    buckets: Dict[int, list] = {}
    for key in olds:
        leaf_mode = _check_dtypes(olds[key], news[key], mode)
        nbytes = int(news[key].nbytes) if hasattr(news[key], "nbytes") \
            else int(np.asarray(news[key]).nbytes)
        ntiles = _leaf_ntiles(nbytes)
        if leaf_mode == "ref" and mode != "ref":
            bid = -2          # kernel tree, ref-only dtype: leaf-wise ref
        elif ntiles > max_bucket_tiles:
            bid = -3                             # standalone launches
        else:
            bid = (ntiles - 1).bit_length()      # pow2 size class
        buckets.setdefault(bid, []).append((key, nbytes, ntiles))
    for bid, leaves in sorted(buckets.items()):
        if bid == -2:
            for key, nbytes, _ in leaves:       # kernel tree, ref-only leaf
                out[key] = changed_blocks(olds[key], news[key], mode="ref")
            continue
        if bid == -3:
            for key, nbytes, _ in leaves:       # big leaf: own launch
                out[key] = changed_blocks(olds[key], news[key], mode=mode,
                                          mirror=mirror, mirror_key=key)
            continue
        out.update(_diff_bucket(bid, leaves, olds, news, mode, mirror))
    return out


def _diff_bucket(bid: int, leaves: list, olds: dict, news: dict,
                 mode: str, mirror: Optional[DeviceMirror]):
    """One fused launch (or one ref pass) over a size bucket's leaves."""
    layout = tuple((key, nb, nt) for key, nb, nt in leaves)
    if mode == "ref":
        o32 = np.concatenate([_ref_tiles(olds[k]) for k, _, _ in leaves])
        n32 = np.concatenate([_ref_tiles(news[k]) for k, _, _ in leaves])
        bitmap, tiles = fused_tiles_ref(o32, n32)
        _count_launch(n32.nbytes, 0)
    else:
        import jax
        import jax.numpy as jnp
        interpret = (mode == "interpret")
        n32 = jnp.concatenate(
            [as_i32_tiles(jnp.asarray(news[k]))[0] for k, _, _ in leaves])
        o32 = mirror.get(("bucket", bid), layout) if mirror is not None \
            else None
        if o32 is None:
            o32 = jnp.concatenate(
                [as_i32_tiles(jax.device_put(olds[k]))[0]
                 for k, _, _ in leaves])
        bm, tiles_dev = fused_delta_tiles(o32, n32, interpret=interpret)
        bitmap = np.asarray(bm)
        tiles = _fetch_compacted(bitmap, tiles_dev, int(n32.nbytes))
        if mirror is not None:
            mirror.swap(("bucket", bid), layout, n32)
    # split the concatenated bitmap + ascending-order compacted tiles back
    # into per-leaf results
    out = {}
    off = pos = 0
    for key, nbytes, ntiles in leaves:
        bm_leaf = bitmap[off:off + ntiles]
        k = int(bm_leaf.sum())
        out[key] = (tiles[pos:pos + k], bm_leaf, nbytes)
        off += ntiles
        pos += k
    return out


def _ref_tiles(x) -> np.ndarray:
    """Numpy mirror of ``as_i32_tiles``: flat i32 view padded to whole
    (8, 1024) tiles."""
    b = np.ascontiguousarray(np.asarray(x)).reshape(-1).view(np.uint8)
    pad = (-b.size) % (TILE * 4)
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    return b.view(np.int32).reshape(-1, SUB, LANE)


def apply_tiles(flat_u8: np.ndarray, tiles: np.ndarray,
                bitmap: np.ndarray) -> np.ndarray:
    """XOR compacted changed tiles into a flat uint8 buffer, in place.

    ``flat_u8`` is the previous state's byte image; tile ``i`` covers bytes
    ``[i*TILE_BYTES, (i+1)*TILE_BYTES)`` of the (padded) stream — the tail
    tile is clipped to the buffer length.  Returns ``flat_u8``.
    """
    nbytes = flat_u8.size
    idx = np.flatnonzero(bitmap)
    if idx.size == 0:
        return flat_u8
    tb = np.ascontiguousarray(tiles[:idx.size]).reshape(idx.size, -1) \
        .view(np.uint8)                       # (k, TILE_BYTES)
    nfull = nbytes // TILE_BYTES
    body = idx < nfull
    if body.any():
        # one reshaped scatter-XOR for every whole tile
        view = flat_u8[:nfull * TILE_BYTES].reshape(nfull, TILE_BYTES)
        view[idx[body]] ^= tb[body]
    for j in np.flatnonzero(~body):           # at most the one tail tile
        s = int(idx[j]) * TILE_BYTES
        e = min(s + TILE_BYTES, nbytes)
        if e > s:
            flat_u8[s:e] ^= tb[j, :e - s]
    return flat_u8
