"""Jit'd wrapper + host-side compaction for differencing snapshots.

Two entry points:

* ``diff_blocks``/``patch_blocks`` — the original one-shot API: materialize
  the full delta, then compact on host (used by tests and small tensors).
* ``changed_blocks``/``tree_changed_blocks`` — the snapshot hot path: a
  probe-then-gather pipeline.  Pass 1 (``changed_bitmap`` kernel) writes
  only one int32 per 32 KiB tile; the host fetches that tiny bitmap, and
  pass 2 gathers + XORs just the changed tiles on device.  Unchanged
  blocks never cross the device→host boundary — the paper's §III-E claim
  that a differencing snapshot costs only the written-to blocks.

The numpy ``ref`` mode mirrors the kernel bit-for-bit (used on hosts
without a TPU runtime; the default when jax is on CPU).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.delta_encode.kernel import (LANE, SUB, TILE,
                                               changed_bitmap, delta_apply,
                                               delta_encode, gather_delta)
from repro.kernels.delta_encode.ref import delta_apply_ref, delta_encode_ref

TILE_BYTES = TILE * 4          # one (8, 1024) i32 tile = 32 KiB of state

# dtypes the Pallas kernel can bitcast; everything else falls back to ref
KERNEL_DTYPES = ("int32", "float32", "bfloat16", "float16", "int16")


def _resolve_mode(mode: str) -> str:
    if mode != "auto":
        return mode
    import jax
    return "tpu" if jax.default_backend() == "tpu" else "ref"


def diff_blocks(old, new, *, mode: str = "interpret"):
    """-> (changed_tiles (k, 8, 1024) i32, bitmap (nblk,), orig_count)."""
    if mode == "ref":
        delta, changed = delta_encode_ref(old, new)
        n = np.asarray(old).size
    else:
        delta, changed, n = delta_encode(old, new,
                                         interpret=(mode == "interpret"))
        delta, changed = np.asarray(delta), np.asarray(changed)
    mask = changed.astype(bool)
    return delta[mask], changed, int(np.asarray(n))


def patch_blocks(old, changed_tiles, bitmap, *, mode: str = "interpret"):
    """Rebuild ``new`` from ``old`` + compacted changed tiles."""
    full = np.zeros((bitmap.size, 8, 1024), np.int32)
    full[bitmap.astype(bool)] = np.asarray(changed_tiles)
    if mode == "ref":
        return delta_apply_ref(old, full)
    out = delta_apply(old, full, interpret=(mode == "interpret"))
    return np.asarray(out)


def changed_blocks(old, new, *, mode: str = "auto", emit: str = "tiles",
                   chunk_bytes: int = 0):
    """Probe-then-gather diff of one tensor.

    -> (changed_tiles (k, 8, 1024) i32 numpy, bitmap (nblk,) i32 numpy,
        nbytes).  ``mode``: "auto" (tpu kernel on TPU, numpy ref
    otherwise), "tpu", "interpret" (Pallas interpreter), or "ref".
    On the kernel paths only the bitmap and the k changed tiles are
    transferred to host.

    ``emit="records"`` is the *upload* mode: instead of raw tiles it
    returns ``(records, new_flat, nbytes)`` where ``records`` maps
    store-chunk index -> XOR bytes for exactly the chunks whose bytes
    changed — the per-chunk payloads ``ChunkStore.put_delta``/``ingest``
    expect — and ``new_flat`` is the updated uint8 host image (the
    caller's next mirror).  Requires ``chunk_bytes``.  Both the snapshot
    differencing path and the volunteer uplink encoder ride this mode.
    """
    host_old = old
    mode = _resolve_mode(mode)
    nbytes = int(old.nbytes) if hasattr(old, "nbytes") \
        else int(np.asarray(old).nbytes)
    if mode != "ref" and str(new.dtype) not in KERNEL_DTYPES:
        mode = "ref"                      # kernel can't bitcast this dtype
    if mode == "ref":
        delta, bitmap = delta_encode_ref(old, new)
        tiles = delta[bitmap.astype(bool)]
    else:
        import jax
        import jax.numpy as jnp
        interpret = (mode == "interpret")
        old = jax.device_put(old)         # upload the mirror ONCE; both
        bm, _ = changed_bitmap(old, new, interpret=interpret)  # passes reuse
        bitmap = np.asarray(bm)           # tiny: one i32 per 32 KiB
        idx = np.flatnonzero(bitmap)
        k = idx.size
        if k == 0:
            tiles = np.zeros((0, SUB, LANE), np.int32)
        else:
            # pad the gather index to the next power of two so gather_delta
            # sees O(log n) distinct shapes instead of recompiling per
            # changed-tile count
            padded = 1 << (k - 1).bit_length()
            idx = np.concatenate([idx,
                                  np.full(padded - k, idx[-1], idx.dtype)])
            tiles = np.asarray(gather_delta(old, new,
                                            jnp.asarray(idx, jnp.int32)))[:k]
    if emit == "tiles":
        return tiles, bitmap, nbytes
    if emit != "records":
        raise ValueError(f"unknown emit mode {emit!r}")
    if chunk_bytes <= 0:
        raise ValueError("emit='records' requires chunk_bytes")
    records, new_flat = chunk_records(np.asarray(host_old), tiles, bitmap,
                                      nbytes, chunk_bytes)
    return records, new_flat, nbytes


def chunk_records(prev: np.ndarray, tiles: np.ndarray, bitmap: np.ndarray,
                  nbytes: int, chunk_bytes: int):
    """Compact changed tiles into store-ready per-chunk XOR records.

    -> (records: {chunk index -> XOR bytes}, new_flat uint8 image).
    Tiles (32 KiB probe granules) rarely align with store chunks; a chunk
    is recorded only when its bytes actually differ, so a tile flip that
    straddles two chunks but only dirties one emits one record.
    """
    old_flat = np.ascontiguousarray(prev).reshape(-1).view(np.uint8)
    if not bitmap.any():
        return {}, old_flat    # unchanged leaf: no records, no host copy
    new_flat = apply_tiles(old_flat.copy(), tiles, bitmap)
    records: dict[int, bytes] = {}
    chunks: set[int] = set()
    for ti in np.flatnonzero(bitmap):
        s = int(ti) * TILE_BYTES
        e = min(s + TILE_BYTES, nbytes)
        if e > s:
            chunks.update(range(s // chunk_bytes,
                                (e - 1) // chunk_bytes + 1))
    for ci in sorted(chunks):
        s, e = ci * chunk_bytes, min((ci + 1) * chunk_bytes, nbytes)
        xor = old_flat[s:e] ^ new_flat[s:e]
        if xor.any():
            records[ci] = xor.tobytes()
    return records, new_flat


def tree_changed_blocks(old_tree, new_tree, *, mode: str = "auto"):
    """Batched per-tensor diff over two pytrees.

    -> {keypath: (changed_tiles, bitmap, nbytes)} — one probe + gather per
    leaf, keyed by ``jax.tree_util.keystr`` paths (the same keys snapshot
    manifests use).
    """
    import jax
    olds = {jax.tree_util.keystr(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(old_tree)[0]}
    news = {jax.tree_util.keystr(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(new_tree)[0]}
    if olds.keys() != news.keys():
        raise ValueError("old/new trees have different structures")
    return {k: changed_blocks(olds[k], news[k], mode=mode) for k in olds}


def apply_tiles(flat_u8: np.ndarray, tiles: np.ndarray,
                bitmap: np.ndarray) -> np.ndarray:
    """XOR compacted changed tiles into a flat uint8 buffer, in place.

    ``flat_u8`` is the previous state's byte image; tile ``i`` covers bytes
    ``[i*TILE_BYTES, (i+1)*TILE_BYTES)`` of the (padded) stream — the tail
    tile is clipped to the buffer length.  Returns ``flat_u8``.
    """
    nbytes = flat_u8.size
    for j, ti in enumerate(np.flatnonzero(bitmap)):
        s = int(ti) * TILE_BYTES
        e = min(s + TILE_BYTES, nbytes)
        if e <= s:
            continue
        tb = np.frombuffer(np.ascontiguousarray(tiles[j]), np.uint8)[:e - s]
        flat_u8[s:e] ^= tb
    return flat_u8
