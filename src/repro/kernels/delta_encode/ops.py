"""Jit'd wrapper + host-side compaction for differencing snapshots.

``diff_blocks`` returns only the changed tiles (+bitmap) — what the snapshot
manager would upload; ``patch_blocks`` reverses it.  numpy fallback mirrors
the kernel exactly (used on hosts without a TPU runtime).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.delta_encode.kernel import (TILE, delta_apply,
                                               delta_encode)
from repro.kernels.delta_encode.ref import delta_apply_ref, delta_encode_ref


def diff_blocks(old, new, *, mode: str = "interpret"):
    """-> (changed_tiles (k, 8, 1024) i32, bitmap (nblk,), orig_count)."""
    if mode == "ref":
        delta, changed = delta_encode_ref(old, new)
        n = np.asarray(old).size
    else:
        delta, changed, n = delta_encode(old, new,
                                         interpret=(mode == "interpret"))
        delta, changed = np.asarray(delta), np.asarray(changed)
    mask = changed.astype(bool)
    return delta[mask], changed, int(np.asarray(n))


def patch_blocks(old, changed_tiles, bitmap, *, mode: str = "interpret"):
    """Rebuild ``new`` from ``old`` + compacted changed tiles."""
    full = np.zeros((bitmap.size, 8, 1024), np.int32)
    full[bitmap.astype(bool)] = np.asarray(changed_tiles)
    if mode == "ref":
        return delta_apply_ref(old, full)
    out = delta_apply(old, full, interpret=(mode == "interpret"))
    return np.asarray(out)
