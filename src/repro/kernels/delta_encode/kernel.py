"""Pallas TPU delta-encode kernel — the differencing-snapshot hot path.

A differencing snapshot (paper §III-E) must stream GBs of parameters and
emit (a) a lossless delta against the previous snapshot and (b) a per-block
changed bitmap so the host stores only written-to blocks.  This is a pure
memory-bound streaming op: read 2 tensors, write 1 + tiny bitmap, zero
FLOPs — ideal Pallas shape: 1-D grid over (8, 1024)-element VMEM tiles
(float32: 32 KiB/tile ×3 streams, deep pipelining, HBM-bound by design).

Deltas are XOR on the int32 bit pattern: exact for any float (including
NaN/Inf payloads), and unchanged blocks are all-zero → maximally
compressible downstream.  decode(old, delta) == new bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024
SUB = 8
TILE = SUB * LANE   # 8192 elements per grid step


def _delta_kernel(old_ref, new_ref, delta_ref, changed_ref):
    o = old_ref[...]
    n = new_ref[...]
    d = jax.lax.bitwise_xor(o, n)
    delta_ref[...] = d
    changed_ref[0] = jnp.any(d != 0).astype(jnp.int32)


def _apply_kernel(old_ref, delta_ref, new_ref):
    new_ref[...] = jax.lax.bitwise_xor(old_ref[...], delta_ref[...])


def _bitmap_kernel(old_ref, new_ref, changed_ref):
    d = jax.lax.bitwise_xor(old_ref[...], new_ref[...])
    changed_ref[0] = jnp.any(d != 0).astype(jnp.int32)


def _as_tiles(flat_i32: jax.Array):
    n = flat_i32.shape[0]
    pad = (-n) % TILE
    if pad:
        flat_i32 = jnp.pad(flat_i32, (0, pad))
    return flat_i32.reshape(-1, SUB, LANE), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_encode(old: jax.Array, new: jax.Array, *,
                 interpret: bool = False):
    """old/new: same-shape arrays -> (delta_i32 tiles, changed (nblocks,)).

    Bit-exact XOR delta over the int32 view, tiled (SUB, LANE)."""
    assert old.shape == new.shape and old.dtype == new.dtype
    o32, _ = _as_tiles(_bitcast_i32(old))
    n32, n = _as_tiles(_bitcast_i32(new))
    nblk = o32.shape[0]
    delta, changed = pl.pallas_call(
        _delta_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nblk, SUB, LANE), jnp.int32),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(o32, n32)
    return delta, changed, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def changed_bitmap(old: jax.Array, new: jax.Array, *,
                   interpret: bool = False):
    """Probe pass: per-tile changed flags ONLY -> (changed (nblk,) i32, n).

    Unlike ``delta_encode`` the full delta never touches HBM — the kernel
    streams both tensors and emits one int32 per (8, 1024) tile.  For a
    mostly-unchanged state this is the whole device-side cost of a
    differencing snapshot; the host reads the tiny bitmap and gathers just
    the changed tiles afterwards (``gather_delta``)."""
    assert old.shape == new.shape and old.dtype == new.dtype
    o32, _ = _as_tiles(_bitcast_i32(old))
    n32, n = _as_tiles(_bitcast_i32(new))
    nblk = o32.shape[0]
    changed = pl.pallas_call(
        _bitmap_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblk,), jnp.int32),
        interpret=interpret,
    )(o32, n32)
    return changed, n


def _fused_kernel(old_ref, new_ref, bitmap_ref, tiles_ref,
                  cnt_ref, stage_ref, sem):
    """Probe + gather in one pass: XOR the tile, flag it, and — only when it
    changed — DMA the compacted tile into the next free output slot.

    The SMEM counter persists across grid steps (TPU grids run sequentially
    per core), so compacted tiles land in ascending tile order and the host
    can recover tile indices from the bitmap alone."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[0] = 0

    d = jax.lax.bitwise_xor(old_ref[...], new_ref[...])
    changed = jnp.any(d != 0)
    bitmap_ref[0] = changed.astype(jnp.int32)

    @pl.when(changed)
    def _emit():
        c = cnt_ref[0]
        stage_ref[...] = d
        copy = pltpu.make_async_copy(stage_ref,
                                     tiles_ref.at[pl.ds(c, 1)], sem)
        copy.start()
        copy.wait()
        cnt_ref[0] = c + 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_delta_records(old: jax.Array, new: jax.Array, *,
                        interpret: bool = False):
    """Single-launch probe+gather -> (bitmap (nblk,) i32, tiles, n).

    ``tiles`` is (nblk, SUB, LANE) i32 with the k changed tiles compacted
    into slots [0, k) in ascending tile order (k = bitmap.sum()); slots
    past k are unwritten.  One kernel launch replaces the
    ``changed_bitmap`` + host sync + ``gather_delta`` pipeline, so the
    device-side cost of a snapshot probe is one pass over old/new and the
    only D2H traffic is the bitmap plus the k changed tiles."""
    assert old.shape == new.shape and old.dtype == new.dtype
    o32, _ = _as_tiles(_bitcast_i32(old))
    n32, n = _as_tiles(_bitcast_i32(new))
    bitmap, tiles = fused_delta_tiles(o32, n32, interpret=interpret)
    return bitmap, tiles, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_delta_tiles(o32: jax.Array, n32: jax.Array, *,
                      interpret: bool = False):
    """Tile-level fused probe+gather over pre-tiled (nblk, SUB, LANE) i32
    inputs — the launch the bucketed tree diff issues once per size bucket
    (inputs are per-leaf ``as_i32_tiles`` views concatenated on device)."""
    nblk = o32.shape[0]
    bitmap, tiles = pl.pallas_call(
        _fused_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec(memory_space=pltpu.ANY)],
        out_shape=[jax.ShapeDtypeStruct((nblk,), jnp.int32),
                   jax.ShapeDtypeStruct((nblk, SUB, LANE), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, SUB, LANE), jnp.int32),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(o32, n32)
    return bitmap, tiles


def as_i32_tiles(x: jax.Array):
    """Public view helper: flat int32 image padded to whole (SUB, LANE)
    tiles -> ((nblk, SUB, LANE) i32, element count before padding).  The
    bucketed tree diff concatenates these per-leaf views so one fused
    launch probes many leaves."""
    return _as_tiles(_bitcast_i32(x))


@jax.jit
def gather_delta(old: jax.Array, new: jax.Array,
                 idx: jax.Array) -> jax.Array:
    """Second pass: XOR only the changed tiles, gathered on device.

    ``idx`` is the changed-tile index vector from ``changed_bitmap``; the
    result is the compacted (k, 8, 1024) i32 delta — the only payload that
    crosses the device→host boundary."""
    o32, _ = _as_tiles(_bitcast_i32(old))
    n32, _ = _as_tiles(_bitcast_i32(new))
    return jax.lax.bitwise_xor(jnp.take(o32, idx, axis=0),
                               jnp.take(n32, idx, axis=0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_apply(old: jax.Array, delta: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """Reconstruct: old ^ delta -> new (same shape/dtype as old)."""
    o32, n = _as_tiles(_bitcast_i32(old))
    new32 = pl.pallas_call(
        _apply_kernel,
        grid=(o32.shape[0],),
        in_specs=[pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, SUB, LANE), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(o32.shape, jnp.int32),
        interpret=interpret,
    )(o32, delta)
    flat = new32.reshape(-1)[:n]
    return _bitcast_back(flat, old.shape, old.dtype)


def _bitcast_i32(x: jax.Array) -> jax.Array:
    x = x.reshape(-1)
    if x.dtype == jnp.int32:
        return x
    if x.dtype in (jnp.float32,):
        return jax.lax.bitcast_convert_type(x, jnp.int32)
    if x.dtype in (jnp.bfloat16, jnp.float16, jnp.int16):
        x16 = jax.lax.bitcast_convert_type(x, jnp.int16)
        pad = (-x16.shape[0]) % 2
        if pad:
            x16 = jnp.pad(x16, (0, pad))
        return jax.lax.bitcast_convert_type(x16.reshape(-1, 2), jnp.int32)
    raise TypeError(f"unsupported dtype {x.dtype}")


def _bitcast_back(flat_i32: jax.Array, shape, dtype) -> jax.Array:
    import numpy as np
    count = int(np.prod(shape)) if shape else 1
    if dtype == jnp.int32:
        return flat_i32[:count].reshape(shape)
    if dtype == jnp.float32:
        return jax.lax.bitcast_convert_type(
            flat_i32, jnp.float32)[:count].reshape(shape)
    x16 = jax.lax.bitcast_convert_type(flat_i32, jnp.int16).reshape(-1)
    return jax.lax.bitcast_convert_type(
        x16[:count].reshape(shape), dtype)
