"""Pure-jnp oracle for pcor — and the 'serial R cor()' baseline the paper
compares against (Fig. 4 Load/Exec)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pcor_ref(x: jax.Array) -> jax.Array:
    """x: (G, S) -> (G, G) Pearson correlation of rows."""
    x = x.astype(jnp.float32)
    xc = x - x.mean(axis=1, keepdims=True)
    norm = jnp.sqrt(jnp.sum(xc * xc, axis=1, keepdims=True))
    z = xc / jnp.maximum(norm, 1e-30)
    return z @ z.T
