"""Jit'd wrapper for pcor with mode dispatch + row-sharded variant.

``pcor_sharded`` mirrors SPRINT's MPI row partitioning: each worker owns a
row strip and computes its strip of the correlation matrix — the work-unit
payload used by the Fig-4 benchmark when run under the volunteer scheduler.
"""
from __future__ import annotations

import jax

from repro.kernels.pcor.kernel import pcor
from repro.kernels.pcor.ref import pcor_ref


def correlate(x: jax.Array, *, block_g: int = 128,
              mode: str = "interpret") -> jax.Array:
    if mode == "ref":
        return pcor_ref(x)
    return pcor(x, block_g=block_g, interpret=(mode == "interpret"))


import functools


@functools.partial(jax.jit, static_argnums=(2,))
def pcor_strip(x: jax.Array, row_start, row_count: int) -> jax.Array:
    """One worker's strip: rows [row_start, row_start+row_count) vs all."""
    import jax.numpy as jnp
    x = x.astype(jnp.float32)

    def z(m):
        mc = m - m.mean(axis=1, keepdims=True)
        n = jnp.sqrt(jnp.sum(mc * mc, axis=1, keepdims=True))
        return mc / jnp.maximum(n, 1e-30)

    zx = z(x)
    zs = jax.lax.dynamic_slice_in_dim(zx, row_start, row_count, axis=0)
    return zs @ zx.T
