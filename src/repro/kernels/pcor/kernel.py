"""Pallas TPU fused Pearson-correlation kernel (SPRINT ``pcor`` case study).

The paper's dependency-workload is SPRINT's parallel correlation over a
gene-expression matrix X (genes x samples).  TPU-native formulation: fuse
row standardization (mean/var over samples) INTO the (gi, gj) output tile
loop, then hit the MXU with x̂ᵢ x̂ⱼᵀ — X is read once per tile pair, the
standardized matrix never round-trips to HBM.

Grid (nG, nG) over (block_g, block_g) output tiles; each program loads two
(block_g, S) row strips into VMEM (default 128×512 f32 = 256 KiB each),
standardizes both in-register, one MXU dot, write one tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pcor_kernel(xi_ref, xj_ref, o_ref, *, s_valid: int):
    xi = xi_ref[...].astype(jnp.float32)          # (bg, S)
    xj = xj_ref[...].astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, xi.shape, 1)
    mask = (col < s_valid).astype(jnp.float32)
    inv_n = 1.0 / s_valid

    def standardize(x):
        x = x * mask
        mean = x.sum(axis=1, keepdims=True) * inv_n
        xc = (x - mean) * mask
        var = (xc * xc).sum(axis=1, keepdims=True)
        return xc * jax.lax.rsqrt(jnp.maximum(var, 1e-30))

    zi = standardize(xi)
    zj = standardize(xj)
    o_ref[...] = jax.lax.dot_general(
        zi, zj, (((1,), (1,)), ((), ()))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def pcor(x: jax.Array, *, block_g: int = 128,
         interpret: bool = False) -> jax.Array:
    """x: (G, S) -> (G, G) Pearson correlation matrix (rows standardized)."""
    g, s = x.shape
    block_g = min(block_g, g)
    pad_g = (-g) % block_g
    pad_s = (-s) % 128                      # lane alignment
    xp = jnp.pad(x, ((0, pad_g), (0, pad_s)))
    gp, sp = xp.shape
    out = pl.pallas_call(
        functools.partial(_pcor_kernel, s_valid=s),
        grid=(gp // block_g, gp // block_g),
        in_specs=[pl.BlockSpec((block_g, sp), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_g, sp), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((block_g, block_g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gp, gp), jnp.float32),
        interpret=interpret,
    )(xp, xp)
    return out[:g, :g]
