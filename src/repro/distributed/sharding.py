"""Logical-axis sharding resolver.

Every tensor in the system (params, optimizer state, activations, caches)
carries *logical* axis names.  A rule table maps logical axes to mesh axes;
the resolver emits a ``PartitionSpec`` per tensor, sharding a dim only when
its size is divisible by the mesh-axis extent (else it replicates and logs —
DESIGN.md §4: e.g. qwen2's 12 heads or 8 KV heads on a model=16 axis).

This is how one capsule ("VM image") runs unmodified on any volunteer mesh:
the sharding is resolved per-topology at attach time, never baked into the
model code.
"""
from __future__ import annotations

import dataclasses
import logging
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger("repro.sharding")

MeshAxes = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class TensorSpec:
    """Shape + dtype + logical axes for one tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = np.float32
    init: str = "normal"          # normal | zeros | ones | slow_decay (A_log)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Default production rule table (DESIGN.md §5).
#   embed   -> FSDP over the data axis (ZeRO-3 style weight sharding)
#   heads/ff/vocab/experts/inner -> tensor parallel over the model axis
#   batch   -> data parallel over (pod, data)
#   cache_len -> model axis (decode KV caches whose head count doesn't divide)
DEFAULT_RULES: dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "inner": "model",            # mamba d_inner
    "state": None,
    "seq": None,
    "cache_len": "model",
    "cache_heads": "model",
    "conv": None,
    "dt_rank": None,
    # --- activation logical axes (distinct from param axes: the FSDP
    # "embed" rule must NOT leak onto activations — GSPMD would otherwise
    # shard activations on embed over the data axis and replicate batch,
    # turning every matmul into a giant partial-sum all-reduce) ---
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "act_inner": "model",
}


@dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict[str, MeshAxes] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    log_replications: bool = True

    def _mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        target = self.rules.get(logical)
        if target is None:
            return ()
        if isinstance(target, str):
            target = (target,)
        return tuple(a for a in target if a in self.mesh.axis_names)

    def spec_for(self, spec_or_axes, shape=None) -> P:
        """PartitionSpec for a TensorSpec (or (axes, shape) pair)."""
        if isinstance(spec_or_axes, TensorSpec):
            axes, shape = spec_or_axes.axes, spec_or_axes.shape
        else:
            axes = spec_or_axes
        assert shape is not None
        parts: list = []
        used: set[str] = set()
        for dim, logical in zip(shape, axes):
            mesh_axes = self._mesh_axes_for(logical)
            # a mesh axis may appear at most once in a PartitionSpec
            mesh_axes = tuple(a for a in mesh_axes if a not in used)
            extent = int(np.prod([self.mesh.shape[a] for a in mesh_axes],
                                 dtype=np.int64)) if mesh_axes else 1
            if mesh_axes and dim % extent == 0 and dim > 0:
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
                used.update(mesh_axes)
            else:
                if mesh_axes and self.log_replications:
                    logger.info(
                        "replicating dim %d (logical %r) on mesh axes %r "
                        "(not divisible by %d)", dim, logical, mesh_axes, extent)
                parts.append(None)
        return P(*parts)

    def sharding_for(self, spec: TensorSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(spec))

    def tree_shardings(self, spec_tree) -> Any:
        """Map a pytree of TensorSpec to NamedShardings."""
        return jax.tree.map(
            self.sharding_for, spec_tree,
            is_leaf=lambda x: isinstance(x, TensorSpec))

    def tree_pspecs(self, spec_tree) -> Any:
        return jax.tree.map(
            self.spec_for, spec_tree,
            is_leaf=lambda x: isinstance(x, TensorSpec))


def abstract_tree(spec_tree, rules: Optional[ShardingRules] = None):
    """TensorSpec tree -> ShapeDtypeStruct tree (no allocation; dry-run)."""
    def mk(s: TensorSpec):
        sharding = rules.sharding_for(s) if rules is not None else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sharding)
    return jax.tree.map(mk, spec_tree,
                        is_leaf=lambda x: isinstance(x, TensorSpec))


def init_tree(spec_tree, rng: jax.Array, scale: float = 0.02):
    """TensorSpec tree -> concrete arrays (smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, TensorSpec))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jax.numpy.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jax.numpy.ones(s.shape, s.dtype))
        elif s.init == "slow_decay":   # mamba A_log init: log(1..d_state)
            import jax.numpy as jnp
            a = jnp.tile(jnp.arange(1, s.shape[-1] + 1, dtype=s.dtype),
                         s.shape[:-1] + (1,)).reshape(s.shape)
            out.append(jnp.log(a))
        else:
            fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[-1], 1)
            std = min(scale, (1.0 / max(fan_in, 1)) ** 0.5)
            out.append(std * jax.random.normal(key, s.shape, s.dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation-constraint context: model code calls ``constrain(x, axes)`` with
# logical axis names; the active ShardingRules (set by the launcher while
# tracing) resolve them to the current mesh.  Outside any context (CPU smoke
# tests) constrain() is the identity, keeping model code mesh-agnostic.
# ---------------------------------------------------------------------------
import contextlib
import threading

_TLS = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional["ShardingRules"]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = prev


def current_rules() -> Optional["ShardingRules"]:
    return getattr(_TLS, "rules", None)


def constrain(x, axes: Sequence[Optional[str]]):
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec_for(tuple(axes), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dim (e.g. layers for lax.scan) to every spec."""
    def st(s: TensorSpec):
        return TensorSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype, s.init)
    return jax.tree.map(st, spec_tree,
                        is_leaf=lambda x: isinstance(x, TensorSpec))


def param_bytes(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, TensorSpec))
    return sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
               for s in leaves)
