"""Int8 gradient compression with error feedback (cross-pod reduces).

At 512+ chips the pod-level all-reduce rides the slow inter-pod links; the
standard trick is to quantize the gradient to int8 with a per-block scale
before the cross-pod reduce and carry the quantization error forward into
the next step (error feedback keeps SGD/Adam convergence unbiased in
practice).  4x fewer wire bytes on the `pod` axis at the cost of one extra
elementwise pass.

Pure-jax, shard-transparent: operates leaf-wise on the gradient pytree, so
GSPMD keeps every tensor's sharding; use inside the train step as

    cg, state = compress(grads, state)
    cg = jax.lax.pmean(cg, 'pod')        # or implicit GSPMD reduce
    grads = decompress(cg)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array          # int8 quantized values (original shape)
    scale: jax.Array      # per-block scales


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


def compress_leaf(g: jax.Array, err: jax.Array):
    """-> (Compressed, new_err).  err is the carried quantization residual."""
    g = g.astype(jnp.float32) + err
    flat = g.reshape(-1)
    pad = _pad_len(flat.shape[0])
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:flat.shape[0]] \
        .reshape(g.shape)
    new_err = g - deq
    return Compressed(q, scale[:, 0]), new_err


def decompress_leaf(c: Compressed, shape, dtype=jnp.float32) -> jax.Array:
    deq = c.q.astype(jnp.float32) * c.scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return deq.reshape(-1)[:n].reshape(shape).astype(dtype)


def init_error(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(grads, err_state):
    leaves, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state)
    out, new_errs = [], []
    for g, e in zip(leaves, errs):
        c, ne = compress_leaf(g, e)
        out.append(c)
        new_errs.append(ne)
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_errs))


def decompress(compressed, like):
    cl = jax.tree.leaves(compressed,
                         is_leaf=lambda x: isinstance(x, Compressed))
    gl, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(
        treedef, [decompress_leaf(c, g.shape, g.dtype)
                  for c, g in zip(cl, gl)])


def wire_bytes(grads) -> tuple[int, int]:
    """(uncompressed f32 bytes, compressed int8+scale bytes)."""
    raw = comp = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        raw += n * 4
        blocks = (n + BLOCK - 1) // BLOCK
        comp += n + blocks * 4
    return raw, comp
