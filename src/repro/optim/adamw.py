"""AdamW + cosine schedule + global-norm clipping (self-contained; no optax).

Optimizer state mirrors the param TensorSpec tree, so m/v inherit the exact
param shardings (FSDP over the data axis, TP over model) — ZeRO-style state
partitioning falls out of the resolver for free.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import TensorSpec


class AdamWState(NamedTuple):
    step: Any           # () int32
    m: Any              # param-tree
    v: Any              # param-tree


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def state_specs(param_specs) -> AdamWState:
    def zeros_like_spec(s: TensorSpec) -> TensorSpec:
        return TensorSpec(s.shape, s.axes, jnp.float32, init="zeros")
    is_spec = lambda x: isinstance(x, TensorSpec)  # noqa: E731
    return AdamWState(
        step=TensorSpec((), (), jnp.int32, init="zeros"),
        m=jax.tree.map(zeros_like_spec, param_specs, is_leaf=is_spec),
        v=jax.tree.map(zeros_like_spec, param_specs, is_leaf=is_spec),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * frac))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step_vec = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_vec = step_vec + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_vec).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree.unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree.unflatten(treedef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
