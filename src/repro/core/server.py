"""V-BOINC project server (paper Fig. 1 flow).

Distributes *capsules* ("VM images") instead of scientific applications, and
answers DepDisk probes: the V-BOINC client asks whether a project has
dependencies (1.1), downloads the DepDisk if so, otherwise creates a fresh
one locally (3).  Transfer accounting reproduces the paper's bandwidth story
(207 MB compressed image / ~3 min at 9 Mbps → bytes-moved metrics here):
``fetch_capsule`` runs the same block-level ``plan_send`` (Wire) dedup as a
volunteer's restore, so a re-attaching client moves only the missing blocks
— typically just the delta objects written since it detached.  With an
``EdgeTier`` attached (``attach_edge``), fetches route through the edge
discovery service and drain from delta caches instead of this store.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import telemetry as tlm
from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import VolunteerScheduler
from repro.core.snapshots import SnapshotManager
from repro.core.uplink import UplinkUpdate, decode_update, push_update


@dataclass
class Project:
    name: str
    capsule: CapsuleSpec
    dep_manifest: Optional[dict] = None      # None = no dependencies
    scheduler: VolunteerScheduler = field(
        default_factory=VolunteerScheduler)
    # attached snapshot chain: a re-attaching volunteer syncs its state
    # blocks through the same fetch path as the capsule itself
    snapshots: Optional[SnapshotManager] = None
    # delta-aware uplink: per-unit updates as they arrive, and the fold of
    # the quorum winner (unit id -> the canonical worker's UplinkUpdate)
    uplink_results: Dict[int, Dict[str, UplinkUpdate]] = field(
        default_factory=dict)
    canonical_updates: Dict[int, UplinkUpdate] = field(default_factory=dict)


@dataclass
class TransferLog:
    bytes_out: int = 0
    bytes_dedup: int = 0
    requests: int = 0
    # route -> serve count ("origin", "dedup", or an edge-cache id) when
    # an edge tier is attached; empty otherwise
    routes: Dict[str, int] = field(default_factory=dict)


@dataclass
class UplinkLog:
    bytes_in: int = 0
    bytes_dedup: int = 0
    accepted: int = 0
    rejected: int = 0


class VBoincServer:
    """Registry + distribution endpoint ("modified BOINC server")."""

    def __init__(self, store: ChunkStore, *,
                 telemetry: Optional[tlm.Telemetry] = None,
                 edge=None):
        self.store = store
        self.tel = tlm.resolve(telemetry)
        self.projects: Dict[str, Project] = {}
        self.transfers: Dict[str, TransferLog] = {}
        self.uplinks: Dict[str, UplinkLog] = {}   # per-project uplink log
        self.account_keys: Dict[str, str] = {}    # weak account keys
        self.edge = None
        if edge is not None:
            self.attach_edge(edge)

    def attach_edge(self, edge) -> None:
        """Front capsule distribution with an ``EdgeTier``: every
        ``fetch_capsule`` routes through its discovery service, so cold
        re-attach waves drain from the caches instead of this store."""
        if edge.origin is not self.store:
            raise ValueError("edge tier must front the server's chunk store")
        self.edge = edge

    def publish(self, project: Project) -> None:
        # fetch_capsule resolves snapshot refs against the SERVER's store
        if (project.snapshots is not None
                and project.snapshots.store is not self.store):
            raise ValueError("project snapshot manager must share the "
                             "server's chunk store")
        # store the capsule manifest as a chunk: its content hash IS the
        # spec's manifest_hash, so capsule distribution rides the same
        # block-level dedup accounting as snapshot state
        self.store.put(json.dumps(project.capsule.manifest(), sort_keys=True,
                                  default=str).encode())
        self.projects[project.name] = project

    def register_user(self, user: str) -> str:
        # derive from sha256, NOT Python's salted hash(): account keys must
        # be stable across server restarts (PYTHONHASHSEED)
        key = f"weak-{hashlib.sha256(user.encode()).hexdigest()[:8]}"
        self.account_keys[user] = key
        return key

    # ---- Fig. 1 steps -------------------------------------------------
    def probe_dependencies(self, project: str) -> Optional[dict]:
        """(1.1) does the project need a DepDisk?"""
        return self.projects[project].dep_manifest

    def fetch_capsule(self, project: str, client_hashes: set[str],
                      account_key: str) -> tuple[CapsuleSpec, list[str], int]:
        """(2) download the capsule; only blocks the client lacks move.

        Returns (spec, missing refs, bytes transferred).  The needed set is
        the capsule manifest plus the project's latest snapshot blocks (when
        a snapshot chain is attached), expanded over delta parents — the
        same ``ChunkStore.plan_send`` (Wire) accounting a volunteer's
        ``restore_latest`` uses, so a re-attaching client downloads only the
        delta objects written since it detached.  With an edge tier
        attached the fetch routes through discovery (``TransferLog.routes``
        records who served it); the plan — and therefore the restored
        bytes — is identical either way."""
        if account_key not in self.account_keys.values():
            raise PermissionError("unknown account key")
        proj = self.projects[project]
        log = self.transfers.setdefault(project, TransferLog())
        log.requests += 1
        needed = [proj.capsule.manifest_hash]
        if proj.snapshots is not None and proj.snapshots.latest():
            man = proj.snapshots.get_manifest(proj.snapshots.latest())
            needed += man.all_refs()
        if self.edge is not None:
            res = self.edge.fetch(needed, client_hashes)
            missing, moved, dedup = res.missing, res.bytes_moved, \
                res.bytes_dedup
            log.routes[res.route] = log.routes.get(res.route, 0) + 1
        else:
            missing, moved, dedup = self.store.plan_send(needed,
                                                         client_hashes)
        log.bytes_out += moved
        log.bytes_dedup += dedup
        return proj.capsule, missing, moved

    def request_work(self, project: str, worker_id: str):
        """(5)/(6) the inner client pulls jobs straight from the server."""
        return self.projects[project].scheduler.request_work(worker_id)

    def report_result(self, project: str, worker_id: str, unit_id: int,
                      result_hash: str,
                      update: Optional[UplinkUpdate] = None) -> bool:
        """(7) results go back directly; server-side quorum validation.

        With ``update`` the volunteer streams its quantized gradient/state
        delta through the chunk store instead of reporting a bare hash:
        only objects the server lacks move up (``plan_recv``), every
        record is re-hashed, and the full chain is resolved before the
        result counts — a corrupt or dangling upload is rejected without
        touching the scheduler.  When the unit's quorum is met, the
        canonical worker's refs are folded into the project's round state
        (``canonical_updates``), which ``resolve_round_update`` serves."""
        proj = self.projects[project]
        if update is not None and not self._ingest_update(
                proj, worker_id, unit_id, update):
            return False
        accepted = proj.scheduler.report(worker_id, unit_id, result_hash)
        # fold every unit whose quorum is now met — with a batched
        # scheduler (ShardedScheduler) a unit may complete at a *later*
        # round flush than the report that supplied the quorum result, so
        # folding keys off unit.completed, not this call's return value
        if proj.uplink_results:
            self._fold_ready(proj)
        return accepted

    def _ingest_update(self, proj: Project, worker_id: str, unit_id: int,
                       update: UplinkUpdate) -> bool:
        log = self.uplinks.setdefault(proj.name, UplinkLog())
        try:
            moved, dedup = push_update(update, self.store,
                                       client_id=worker_id)
        except (IOError, KeyError):
            log.rejected += 1
            return False
        try:
            decode_update(self.store, update)    # chain must fully resolve
        except (IOError, KeyError):
            # records landed (content-addressed, so harmless) but the
            # update is undecodable — claw back the per-client accounting
            # so the worker earns no transfer credit for a rejected result
            clog = self.store.uplinks[worker_id]
            clog["bytes_in"] -= moved
            clog["bytes_dedup"] -= dedup
            clog["rejected"] += 1
            log.rejected += 1
            return False
        log.bytes_in += moved
        log.bytes_dedup += dedup
        log.accepted += 1
        proj.uplink_results.setdefault(unit_id, {})[worker_id] = update
        self._prune(proj.uplink_results)
        return True

    # retained folded rounds: enough for any validator/re-attach window,
    # bounded so long trainings don't accumulate every round ever folded
    UPLINK_KEEP = 256

    def _prune(self, d: Dict[int, object]) -> None:
        while len(d) > self.UPLINK_KEEP:      # oldest unit ids first
            d.pop(next(iter(d)))

    def _fold_ready(self, proj: Project) -> None:
        """Fold canonical updates for every completed unit still holding
        replica uploads (bounded by UPLINK_KEEP)."""
        for uid in list(proj.uplink_results):
            unit = proj.scheduler.units.get(uid)
            if unit is not None and unit.completed:
                self._fold_canonical(proj, uid)

    def _fold_canonical(self, proj: Project, unit_id: int) -> None:
        unit = proj.scheduler.units.get(unit_id)
        ups = proj.uplink_results.get(unit_id, {})
        if unit is None or unit.canonical is None:
            return
        for wid, h in unit.results.items():
            if h == unit.canonical and wid in ups:
                proj.canonical_updates[unit_id] = ups[wid]
                proj.uplink_results.pop(unit_id)   # replicas folded; drop
                self._prune(proj.canonical_updates)
                if self.tel.tracing:
                    self.tel.event("uplink_fold", unit=unit_id, worker=wid)
                break

    def resolve_round_update(self, project: str, unit_id: int):
        """Fold a validated unit's delta refs into quantized leaves.

        -> {keypath: Compressed} resolved against the SERVER's store — the
        canonical round state the uplink reconstructs, proving the server
        no longer depends on the volunteer re-shipping full gradients."""
        proj = self.projects[project]
        self._fold_ready(proj)      # batched schedulers fold lazily
        update = proj.canonical_updates[unit_id]
        return decode_update(self.store, update)

    # ---- replica failover ---------------------------------------------
    def failover(self, index: Optional[int] = None) -> int:
        """Primary store loss: mark it down and promote a replica so
        ``fetch_capsule``/``report_result`` keep serving.

        Requires the server's store to be a ``ReplicaSet``.  Promotes the
        designated member ``index``, or the best-stocked alive replica when
        omitted.  Returns the promoted member index — every registry,
        scheduler and uplink table is untouched; only the object reads and
        writes move to the survivor."""
        store = self.store
        if not hasattr(store, "promote_best"):
            raise RuntimeError("failover needs a replicated store "
                               "(ReplicaSet); this server has a single "
                               "ChunkStore")
        old = store.primary_index
        store.mark_down(old)
        try:
            if index is None:
                promoted = store.promote_best()
            else:
                store.promote(index)
                promoted = index
        except (IndexError, ValueError, IOError):
            store.mark_up(old)     # bad target must not brick the primary
            raise
        if self.tel.tracing:
            self.tel.event("failover", old=old, promoted=promoted)
        return promoted

    def fail_shard(self, project: str, index: int) -> Dict[str, int]:
        """Scheduler-shard loss: reassign the dead shard's key range and
        open units to the survivors (the control-plane analogue of store
        ``failover``).  Requires the project's scheduler to be a
        ``ShardedScheduler``."""
        sched = self.projects[project].scheduler
        if not hasattr(sched, "fail_shard"):
            raise RuntimeError("fail_shard needs a sharded scheduler "
                               "(ShardedScheduler); this project runs a "
                               "single VolunteerScheduler")
        return sched.fail_shard(index)

    def scheduler_stats(self, project: str) -> Dict[str, int]:
        """Aggregated scheduler counters (plus per-shard totals when the
        project's scheduler is sharded)."""
        return dict(self.projects[project].scheduler.stats)

    # ---- §IV-C capacity -----------------------------------------------
    def tasks_per_day_capacity(self, dispatch_us: float,
                               validate_us: float) -> float:
        """Derived server capacity from measured per-op costs."""
        per_task_s = (dispatch_us + validate_us) / 1e6
        return 86_400.0 / per_task_s
