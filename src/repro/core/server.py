"""V-BOINC project server (paper Fig. 1 flow).

Distributes *capsules* ("VM images") instead of scientific applications, and
answers DepDisk probes: the V-BOINC client asks whether a project has
dependencies (1.1), downloads the DepDisk if so, otherwise creates a fresh
one locally (3).  Transfer accounting reproduces the paper's bandwidth story
(207 MB compressed image / ~3 min at 9 Mbps → bytes-moved metrics here, with
chunk dedup meaning a re-attach moves only missing chunks).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import VolunteerScheduler


@dataclass
class Project:
    name: str
    capsule: CapsuleSpec
    dep_manifest: Optional[dict] = None      # None = no dependencies
    scheduler: VolunteerScheduler = field(
        default_factory=VolunteerScheduler)


@dataclass
class TransferLog:
    bytes_out: int = 0
    bytes_dedup: int = 0
    requests: int = 0


class VBoincServer:
    """Registry + distribution endpoint ("modified BOINC server")."""

    def __init__(self, store: ChunkStore):
        self.store = store
        self.projects: Dict[str, Project] = {}
        self.transfers: Dict[str, TransferLog] = {}
        self.account_keys: Dict[str, str] = {}    # weak account keys

    def publish(self, project: Project) -> None:
        self.projects[project.name] = project

    def register_user(self, user: str) -> str:
        key = f"weak-{hash(user) & 0xffffffff:08x}"
        self.account_keys[user] = key
        return key

    # ---- Fig. 1 steps -------------------------------------------------
    def probe_dependencies(self, project: str) -> Optional[dict]:
        """(1.1) does the project need a DepDisk?"""
        return self.projects[project].dep_manifest

    def fetch_capsule(self, project: str, client_hashes: set[str],
                      account_key: str) -> tuple[CapsuleSpec, list[str], int]:
        """(2) download the capsule; only chunks the client lacks move.

        Returns (spec, missing chunk hashes, bytes transferred)."""
        if account_key not in self.account_keys.values():
            raise PermissionError("unknown account key")
        proj = self.projects[project]
        log = self.transfers.setdefault(project, TransferLog())
        log.requests += 1
        # capsule payload chunks = manifest hash (specs are tiny; any model
        # weights ride the chunk store like DepDisks)
        needed = [proj.capsule.manifest_hash]
        missing = [h for h in needed if h not in client_hashes]
        moved = sum(len(h) for h in missing)   # manifest bytes (demo scale)
        log.bytes_out += moved
        log.bytes_dedup += sum(len(h) for h in needed) - moved
        return proj.capsule, missing, moved

    def request_work(self, project: str, worker_id: str):
        """(5)/(6) the inner client pulls jobs straight from the server."""
        return self.projects[project].scheduler.request_work(worker_id)

    def report_result(self, project: str, worker_id: str, unit_id: int,
                      result_hash: str) -> bool:
        """(7) results go back directly; server-side quorum validation."""
        return self.projects[project].scheduler.report(
            worker_id, unit_id, result_hash)

    # ---- §IV-C capacity -----------------------------------------------
    def tasks_per_day_capacity(self, dispatch_us: float,
                               validate_us: float) -> float:
        """Derived server capacity from measured per-op costs."""
        per_task_s = (dispatch_us + validate_us) / 1e6
        return 86_400.0 / per_task_s
