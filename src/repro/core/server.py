"""V-BOINC project server (paper Fig. 1 flow).

Distributes *capsules* ("VM images") instead of scientific applications, and
answers DepDisk probes: the V-BOINC client asks whether a project has
dependencies (1.1), downloads the DepDisk if so, otherwise creates a fresh
one locally (3).  Transfer accounting reproduces the paper's bandwidth story
(207 MB compressed image / ~3 min at 9 Mbps → bytes-moved metrics here):
``fetch_capsule`` runs the same block-level ``transfer_plan`` dedup as a
volunteer's restore, so a re-attaching client moves only the missing blocks
— typically just the delta objects written since it detached.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.capsule import CapsuleSpec
from repro.core.chunkstore import ChunkStore
from repro.core.scheduler import VolunteerScheduler
from repro.core.snapshots import SnapshotManager


@dataclass
class Project:
    name: str
    capsule: CapsuleSpec
    dep_manifest: Optional[dict] = None      # None = no dependencies
    scheduler: VolunteerScheduler = field(
        default_factory=VolunteerScheduler)
    # attached snapshot chain: a re-attaching volunteer syncs its state
    # blocks through the same fetch path as the capsule itself
    snapshots: Optional[SnapshotManager] = None


@dataclass
class TransferLog:
    bytes_out: int = 0
    bytes_dedup: int = 0
    requests: int = 0


class VBoincServer:
    """Registry + distribution endpoint ("modified BOINC server")."""

    def __init__(self, store: ChunkStore):
        self.store = store
        self.projects: Dict[str, Project] = {}
        self.transfers: Dict[str, TransferLog] = {}
        self.account_keys: Dict[str, str] = {}    # weak account keys

    def publish(self, project: Project) -> None:
        # fetch_capsule resolves snapshot refs against the SERVER's store
        if (project.snapshots is not None
                and project.snapshots.store is not self.store):
            raise ValueError("project snapshot manager must share the "
                             "server's chunk store")
        # store the capsule manifest as a chunk: its content hash IS the
        # spec's manifest_hash, so capsule distribution rides the same
        # block-level dedup accounting as snapshot state
        self.store.put(json.dumps(project.capsule.manifest(), sort_keys=True,
                                  default=str).encode())
        self.projects[project.name] = project

    def register_user(self, user: str) -> str:
        # derive from sha256, NOT Python's salted hash(): account keys must
        # be stable across server restarts (PYTHONHASHSEED)
        key = f"weak-{hashlib.sha256(user.encode()).hexdigest()[:8]}"
        self.account_keys[user] = key
        return key

    # ---- Fig. 1 steps -------------------------------------------------
    def probe_dependencies(self, project: str) -> Optional[dict]:
        """(1.1) does the project need a DepDisk?"""
        return self.projects[project].dep_manifest

    def fetch_capsule(self, project: str, client_hashes: set[str],
                      account_key: str) -> tuple[CapsuleSpec, list[str], int]:
        """(2) download the capsule; only blocks the client lacks move.

        Returns (spec, missing refs, bytes transferred).  The needed set is
        the capsule manifest plus the project's latest snapshot blocks (when
        a snapshot chain is attached), expanded over delta parents — the
        same ``ChunkStore.transfer_plan`` accounting a volunteer's
        ``restore_latest`` uses, so a re-attaching client downloads only the
        delta objects written since it detached."""
        if account_key not in self.account_keys.values():
            raise PermissionError("unknown account key")
        proj = self.projects[project]
        log = self.transfers.setdefault(project, TransferLog())
        log.requests += 1
        needed = [proj.capsule.manifest_hash]
        if proj.snapshots is not None and proj.snapshots.latest():
            man = proj.snapshots.get_manifest(proj.snapshots.latest())
            needed += man.all_refs()
        missing, moved, dedup = self.store.transfer_plan(needed,
                                                         client_hashes)
        log.bytes_out += moved
        log.bytes_dedup += dedup
        return proj.capsule, missing, moved

    def request_work(self, project: str, worker_id: str):
        """(5)/(6) the inner client pulls jobs straight from the server."""
        return self.projects[project].scheduler.request_work(worker_id)

    def report_result(self, project: str, worker_id: str, unit_id: int,
                      result_hash: str) -> bool:
        """(7) results go back directly; server-side quorum validation."""
        return self.projects[project].scheduler.report(
            worker_id, unit_id, result_hash)

    # ---- §IV-C capacity -----------------------------------------------
    def tasks_per_day_capacity(self, dispatch_us: float,
                               validate_us: float) -> float:
        """Derived server capacity from measured per-op costs."""
        per_task_s = (dispatch_us + validate_us) / 1e6
        return 86_400.0 / per_task_s
