"""Content-addressed chunk store with raw and *delta* objects.

The substrate for differencing snapshots (paper §III-E).  Two object
kinds live side by side:

* **raw**   — chunk bytes, addressed by ``sha256(bytes)`` (refs are bare
  hex, as in v1 manifests);
* **delta** — ``parent_ref + zero-run-RLE-compressed XOR payload``,
  addressed as ``"d:" + sha256(record)``.  The analogue of a VirtualBox
  differencing image: a block written after a snapshot stores only its
  XOR against the parent block, so incremental cost is exactly the
  changed bytes (the paper's Table II behaviour by construction).

Delta records carry their chain depth; ``put_delta`` transparently
*rebases* — materializes a fresh raw object — when the chain would exceed
``max_chain`` (bounding restore cost) or when the encoded delta would be
no smaller than the chunk itself.  ``resolve`` reconstructs any ref: XOR
is associative, so a chain folds into the root base in one pass.  GC
marks the *closure* of live refs (a delta keeps its parents alive even
when the parent's manifest has been trimmed).

Integrity = re-hash on read for both kinds (the paper's "trusted
application" concern: a volunteer can verify every byte it receives).

Every transfer in the system — capsule/snapshot downlink, volunteer
uplink, replica fan-out and edge-cache demand-fill — speaks one **Wire**
protocol of four verbs:

* ``plan_send(refs, peer_has)`` — source-side planning: which of
  ``refs``'s delta closure a peer holding ``peer_has`` still needs, sized
  from this store's own objects (-> :class:`TransferPlan`);
* ``plan_recv(offered, client_id=)`` — sink-side planning: which of a
  client's offered objects this store lacks (sizes are the *client's*
  claim, for planning only — verified bytes accumulate in ``recv``);
* ``send(refs)`` — the wire image of objects: ref -> packed bytes (raw
  chunk bytes, or the packed delta record).  The receiver re-hashes
  everything, so the wire needs no extra framing;
* ``recv(records, client_id=)`` — validate-and-store: every ref is
  recomputed from the record bytes and delta chains must land
  parents-first with truthful depths, or nothing is written.

The pre-Wire names (``transfer_plan``, ``ingest_plan``, ``ingest``,
``export_records``) remain as thin deprecated shims.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import struct
import threading
import time
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Protocol, runtime_checkable

from repro.core import telemetry as tlm

import numpy as np

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB
DELTA_PREFIX = "d:"
_DELTA_MAGIC = b"VBD1"


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def is_delta_ref(ref: str) -> bool:
    return ref.startswith(DELTA_PREFIX)


# -- zero-run RLE ----------------------------------------------------------
# XOR payloads of a partly-changed chunk are mostly zero; encode as a token
# stream of [tag u8][len u32] where tag 0 = zero run, tag 1 = literal run
# (+ bytes).  Runs shorter than 8 bytes are folded into literals so worst
# case stays near 1x; callers fall back to the uncompressed payload when
# RLE does not win.

_MIN_ZERO_RUN = 8


def rle_zero_encode(data: bytes) -> bytes:
    a = np.frombuffer(data, np.uint8)
    if a.size == 0:
        return b""
    nz = a != 0
    # bail before the per-run loop when RLE cannot win: mostly-nonzero
    # payloads, or so many short runs (dense interleaving, e.g. fp32
    # tensors where every low byte changed) that token overhead dominates.
    # The single-literal fallback is 5 bytes longer than the input, so
    # put_delta's "payload >= xor" check discards it in O(1).
    def _literal():
        return b"\x01" + struct.pack("<I", a.size) + data

    if int(np.count_nonzero(nz)) * 2 > a.size:
        return _literal()
    change = np.flatnonzero(np.diff(nz.view(np.int8))) + 1
    if change.size > a.size // 64:        # avg run < 64 B: not worth it
        return _literal()
    starts = np.concatenate(([0], change))
    ends = np.concatenate((change, [a.size]))
    out = bytearray()
    lit_start = None
    for s, e in zip(starts, ends):
        if not nz[s] and e - s >= _MIN_ZERO_RUN:
            if lit_start is not None:
                out += b"\x01" + struct.pack("<I", s - lit_start)
                out += data[lit_start:s]
                lit_start = None
            out += b"\x00" + struct.pack("<I", e - s)
        elif lit_start is None:
            lit_start = s
    if lit_start is not None:
        out += b"\x01" + struct.pack("<I", a.size - lit_start)
        out += data[lit_start:]
    return bytes(out)


def rle_zero_decode(payload: bytes, out_len: int) -> bytes:
    out = bytearray(out_len)
    pos = i = 0
    while i < len(payload):
        tag = payload[i]
        n = struct.unpack_from("<I", payload, i + 1)[0]
        i += 5
        if tag == 1:
            out[pos:pos + n] = payload[i:i + n]
            i += n
        pos += n
    return bytes(out)


def _xor_bytes(a: bytes, b: bytes) -> bytes:
    return (np.frombuffer(a, np.uint8) ^ np.frombuffer(b, np.uint8)).tobytes()


@dataclass
class DeltaRecord:
    parent: str
    depth: int
    raw_len: int
    payload: bytes            # XOR vs parent, possibly RLE-compressed
    compressed: bool

    def pack(self) -> bytes:
        p = self.parent.encode()
        return (_DELTA_MAGIC
                + struct.pack("<HIBH", self.depth, self.raw_len,
                              int(self.compressed), len(p))
                + p + self.payload)

    @classmethod
    def unpack(cls, rec: bytes) -> "DeltaRecord":
        if rec[:4] != _DELTA_MAGIC:
            raise IOError("not a delta record")
        depth, raw_len, comp, plen = struct.unpack_from("<HIBH", rec, 4)
        off = 4 + struct.calcsize("<HIBH")
        parent = rec[off:off + plen].decode()
        return cls(parent, depth, raw_len, rec[off + plen:], bool(comp))

    def xor(self) -> bytes:
        return (rle_zero_decode(self.payload, self.raw_len)
                if self.compressed else self.payload)


@dataclass
class TransferPlan:
    """One planned object transfer, in either direction, on the Wire.

    ``refs`` are the objects that must move, ``bytes_moved`` their wire
    size, ``bytes_dedup`` the bytes the receiving side already held (the
    dedup savings the credit accounting reports).  Unpacks as the legacy
    ``(missing, moved, dedup)`` triple so callers written against
    ``transfer_plan``/``ingest_plan`` keep working unchanged."""

    refs: List[str]
    bytes_moved: int
    bytes_dedup: int

    def _astuple(self) -> tuple:
        return (self.refs, self.bytes_moved, self.bytes_dedup)

    def __iter__(self):
        return iter(self._astuple())

    def __len__(self) -> int:
        return 3

    def __getitem__(self, i):
        return self._astuple()[i]

    def __bool__(self) -> bool:
        return bool(self.refs)


@runtime_checkable
class Wire(Protocol):
    """The unified transfer surface every object mover speaks.

    Implemented by :class:`ChunkStore`, proxied by ``ReplicaSet`` (writes
    enqueue for replication) and served at the edge by ``EdgeCache`` —
    downlink capsule fetch, uplink result ingest, replica ``pump`` and
    edge demand-fill are all ``plan_*`` + ``send`` + ``recv`` exchanges
    between two Wire endpoints."""

    def plan_send(self, refs: Iterable[str],
                  peer_has: set) -> "TransferPlan": ...

    def plan_recv(self, offered: Dict[str, int], *,
                  client_id: Optional[str] = None) -> "TransferPlan": ...

    def send(self, refs: Iterable[str]) -> Dict[str, bytes]: ...

    def recv(self, records: Dict[str, bytes], *,
             client_id: Optional[str] = None) -> int: ...


def _warn_wire(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; speak the Wire protocol "
                  f"({new}) instead", DeprecationWarning, stacklevel=3)


class ChunkStore:
    """Deduplicating raw+delta object store with closure-marking GC."""

    def __init__(self, root: Optional[os.PathLike] = None,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 max_chain: int = 8, *,
                 telemetry: Optional["tlm.Telemetry"] = None):
        self.chunk_bytes = int(chunk_bytes)
        self.max_chain = int(max_chain)
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            (self.root / "objects").mkdir(parents=True, exist_ok=True)
            (self.root / "deltas").mkdir(parents=True, exist_ok=True)
        self._mem: Dict[str, bytes] = {}
        self._mem_delta: Dict[str, bytes] = {}
        self._depths: Dict[str, int] = {}        # delta ref -> chain depth
        self._lock = threading.Lock()
        # serializes mark+sweep against concurrent writers: a background
        # SnapshotWriter holds this across "write objects + commit manifest"
        # so a GC can never collect its live set between the two.  Reentrant
        # because gc() runs under a caller's guard (DiskSet.gc_all collects
        # live refs from many managers under the same lock).
        self.gc_lock = threading.RLock()
        # telemetry registry behind the historical dict shape: .stats is
        # a read-only live view, writes go through .metrics
        self.tel = tlm.resolve(telemetry)
        scope = self.tel.scope("chunkstore")
        self.metrics = scope.counters(
            "put_bytes", "dedup_bytes", "get_bytes", "put_chunks",
            "dedup_chunks", "delta_chunks", "rebased", "ingest_bytes",
            "ingest_dedup_bytes", "ingest_records", "egress_bytes")
        self.stats = scope.view()
        # per-client uplink accounting (client id -> counters); the server
        # credits volunteers by the deduped bytes they actually moved
        self.uplinks: Dict[str, Dict[str, int]] = {}

    # -- raw object layer --------------------------------------------------
    def _path(self, h: str) -> Path:
        return self.root / "objects" / h[:2] / h[2:]

    def _dpath(self, h: str) -> Path:
        return self.root / "deltas" / h[:2] / h[2:]

    def has(self, ref: str) -> bool:
        if is_delta_ref(ref):
            h = ref[len(DELTA_PREFIX):]
            if self.root is None:
                return h in self._mem_delta
            return h in self._mem_delta or self._dpath(h).exists()
        if self.root is None:
            return ref in self._mem
        return ref in self._mem or self._path(ref).exists()

    def put(self, data: bytes) -> str:
        h = sha256(data)
        with self._lock:
            if self.has(h):
                self.metrics.dedup_bytes.inc(len(data))
                self.metrics.dedup_chunks.inc()
                return h
            self.metrics.put_bytes.inc(len(data))
            self.metrics.put_chunks.inc()
            if self.tel.tracing:
                self.tel.event("put", ref=h[:16], bytes=len(data))
            if self.root is None:
                self._mem[h] = bytes(data)
            else:
                self._atomic_write(self._path(h), data)
        return h

    @staticmethod
    def _atomic_write(p: Path, data: bytes) -> None:
        """Crash-consistent publish: write a uniquely-named temp file in the
        same directory, then ``os.replace`` it into place.  A crash mid-write
        leaves only a ``*.tmp`` orphan (never a torn object under a valid
        ref); the pid suffix keeps concurrent writers from clobbering each
        other's temp files."""
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_name(f"{p.name}.{os.getpid()}.tmp")
        tmp.write_bytes(data)
        os.replace(tmp, p)

    def get(self, h: str) -> bytes:
        if self.root is None or h in self._mem:
            data = self._mem[h]
        else:
            data = self._path(h).read_bytes()
        if sha256(data) != h:  # integrity (sandbox/trust analogue)
            raise IOError(f"chunk {h[:12]} failed integrity check")
        self.metrics.get_bytes.inc(len(data))
        return data

    def delete(self, ref: str) -> None:
        with self._lock:
            if is_delta_ref(ref):
                h = ref[len(DELTA_PREFIX):]
                self._mem_delta.pop(h, None)
                self._depths.pop(ref, None)
                if self.root is not None and self._dpath(h).exists():
                    self._dpath(h).unlink()
                return
            self._mem.pop(ref, None)
            if self.root is not None:
                p = self._path(ref)
                if p.exists():
                    p.unlink()

    def all_refs(self) -> Iterable[str]:
        out = set(self._mem)
        out.update(DELTA_PREFIX + h for h in self._mem_delta)
        if self.root is not None:
            # *.tmp orphans from a crashed writer are not objects
            for sub in (self.root / "objects").glob("*/*"):
                if not sub.name.endswith(".tmp"):
                    out.add(sub.parent.name + sub.name)
            for sub in (self.root / "deltas").glob("*/*"):
                if not sub.name.endswith(".tmp"):
                    out.add(DELTA_PREFIX + sub.parent.name + sub.name)
        return out

    # kept for callers of the v1 API
    all_hashes = all_refs

    # -- delta object layer ------------------------------------------------
    def put_delta(self, parent_ref: str, xor_bytes: bytes, *,
                  full_bytes: Optional[bytes] = None) -> str:
        """Store one changed block as a delta against ``parent_ref``.

        Returns the new ref.  Transparently rebases to a raw object when
        the chain would exceed ``max_chain`` or the delta record would be
        no smaller than the chunk itself (``full_bytes``, when given,
        avoids a resolve to materialize the rebase)."""
        depth = self.ref_depth(parent_ref) + 1
        if depth > self.max_chain:
            full = full_bytes if full_bytes is not None else _xor_bytes(
                self.resolve(parent_ref), xor_bytes)
            self.metrics.rebased.inc()
            return self.put(full)
        payload = rle_zero_encode(xor_bytes)
        compressed = True
        if len(payload) >= len(xor_bytes):
            payload, compressed = xor_bytes, False
        rec = DeltaRecord(parent_ref, depth, len(xor_bytes), payload,
                          compressed).pack()
        if full_bytes is not None and len(rec) >= len(full_bytes):
            return self.put(full_bytes)   # delta no cheaper than a base
        return self._write_delta(sha256(rec), rec, depth)

    def _write_delta(self, h: str, rec: bytes, depth: int) -> str:
        """Store a packed delta record under its content hash."""
        ref = DELTA_PREFIX + h
        with self._lock:
            if self.has(ref):
                self.metrics.dedup_bytes.inc(len(rec))
                self.metrics.dedup_chunks.inc()
            else:
                self.metrics.put_bytes.inc(len(rec))
                self.metrics.put_chunks.inc()
                self.metrics.delta_chunks.inc()
                if self.tel.tracing:
                    self.tel.event("put", ref=ref[:16], bytes=len(rec),
                                   delta=True, depth=depth)
                if self.root is None:
                    self._mem_delta[h] = rec
                else:
                    self._atomic_write(self._dpath(h), rec)
        self._depths[ref] = depth
        return ref

    def _delta_bytes(self, h: str) -> bytes:
        if self.root is None or h in self._mem_delta:
            rec = self._mem_delta[h]
        else:
            rec = self._dpath(h).read_bytes()
        if sha256(rec) != h:
            raise IOError(f"delta {h[:12]} failed integrity check")
        return rec

    def _get_delta(self, ref: str) -> DeltaRecord:
        rec = self._delta_bytes(ref[len(DELTA_PREFIX):])
        self.metrics.get_bytes.inc(len(rec))
        return DeltaRecord.unpack(rec)

    def ref_depth(self, ref: str) -> int:
        """Chain depth of a ref (0 for raw objects)."""
        if not is_delta_ref(ref):
            return 0
        d = self._depths.get(ref)
        if d is None:
            d = self._get_delta(ref).depth
            self._depths[ref] = d
        return d

    def resolve(self, ref: str) -> bytes:
        """Reconstruct a block from its base chain (raw refs pass through)."""
        if not is_delta_ref(ref):
            return self.get(ref)
        acc: Optional[bytes] = None
        while is_delta_ref(ref):
            rec = self._get_delta(ref)
            xor = rec.xor()
            acc = xor if acc is None else _xor_bytes(acc, xor)
            ref = rec.parent
        return _xor_bytes(self.get(ref), acc)

    def object_size(self, ref: str) -> int:
        """Stored (on-wire) byte size of one object."""
        if not self.has(ref):
            raise KeyError(f"object {ref[:14]} not in store")
        if is_delta_ref(ref):
            h = ref[len(DELTA_PREFIX):]
            if h in self._mem_delta:
                return len(self._mem_delta[h])
            return self._dpath(h).stat().st_size
        if ref in self._mem:
            return len(self._mem[ref])
        return self._path(ref).stat().st_size

    # -- tensor layer ------------------------------------------------------
    def put_buffer(self, buf: memoryview) -> list[str]:
        """Chunk + store one tensor's bytes; returns the ref list."""
        buf = memoryview(buf).cast("B")
        return [self.put(bytes(buf[o:o + self.chunk_bytes]))
                for o in range(0, max(len(buf), 1), self.chunk_bytes)]

    def get_buffer(self, refs: list[str]) -> bytes:
        return b"".join(self.get(h) for h in refs)

    def resolve_buffer(self, refs: list[str]) -> bytes:
        """Like ``get_buffer`` but follows delta chains."""
        return b"".join(self.resolve(r) for r in refs)

    # -- dedup accounting / GC ---------------------------------------------
    def live_closure(self, refs: Iterable[str]) -> set[str]:
        """Expand refs over delta parents — everything needed to resolve."""
        seen: set[str] = set()
        stack = list(refs)
        while stack:
            r = stack.pop()
            if r in seen:
                continue
            seen.add(r)
            if is_delta_ref(r):
                stack.append(self._get_delta(r).parent)
        return seen

    # -- Wire: planning (both directions) ----------------------------------
    def plan_send(self, refs: Iterable[str],
                  peer_has: set[str]) -> TransferPlan:
        """Source-side Wire planning: block-level dedup accounting shared
        by capsule fetch, volunteer restore and edge prefetch.

        Which of ``refs``'s delta closure a peer holding ``peer_has``
        still needs, sized from this store.  A peer that already holds a
        delta's parents downloads only the delta record."""
        needed = self.live_closure(refs)
        missing = sorted(r for r in needed if r not in peer_has)
        moved = sum(self.object_size(r) for r in missing)
        dedup = sum(self.object_size(r) for r in needed if r in peer_has)
        return TransferPlan(missing, moved, dedup)

    def plan_recv(self, offered: Dict[str, int], *,
                  client_id: Optional[str] = None) -> TransferPlan:
        """Sink-side Wire planning: which of a client's offered objects
        this store still needs (the uplink mirror of ``plan_send``).

        ``offered`` maps ref -> wire size as measured by the *client's*
        store (this store cannot size objects it does not hold yet).  The
        moved figure is the client's claim and is for *planning only*;
        credit-bearing ``bytes_in`` accumulates in ``recv`` from bytes
        actually verified and written, so an inflated offer cannot mint
        credit.  Dedup is sized from this store's own copies (it holds
        them), so it is verified here."""
        needed = sorted(r for r in offered if not self.has(r))
        moved = sum(offered[r] for r in needed)
        dedup = sum(self.object_size(r) for r in offered if self.has(r))
        self.metrics.ingest_dedup_bytes.inc(dedup)
        if client_id is not None:
            self._client_log(client_id)["bytes_dedup"] += dedup
        return TransferPlan(needed, moved, dedup)

    # -- Wire: data movement -----------------------------------------------
    def send(self, refs: Iterable[str]) -> Dict[str, bytes]:
        """Wire image of objects: ref -> packed bytes (raw chunk bytes, or
        the packed delta record).  The receiving endpoint's ``recv``
        recomputes every hash, so the wire needs no extra framing.  Bytes
        leaving this store count in ``egress_bytes`` — the primary-egress
        figure the edge tier exists to shrink."""
        out: Dict[str, bytes] = {}
        for r in refs:
            if is_delta_ref(r):
                out[r] = self._delta_bytes(r[len(DELTA_PREFIX):])
            else:
                out[r] = self.get(r)
        self.metrics.egress_bytes.inc(sum(len(b) for b in out.values()))
        return out

    def _client_log(self, client_id: str) -> Dict[str, int]:
        return self.uplinks.setdefault(
            client_id, {"bytes_in": 0, "bytes_dedup": 0, "records": 0,
                        "rejected": 0})

    def recv(self, records: Dict[str, bytes], *,
             client_id: Optional[str] = None) -> int:
        """Validate and store peer-built objects (the Wire write path:
        uplink push, replica delivery, edge demand-fill).

        Every ref is recomputed from the record bytes (content addressing
        doubles as integrity — a tampered upload cannot land under a valid
        ref), and a delta record's parent must already exist here or
        arrive in the same batch; records are applied parents-first so a
        batch may carry a whole chain.  Returns bytes written (dedup'd
        records cost nothing); raises ``IOError`` on a corrupt or
        dangling record, writing none of the batch."""
        raws: List[tuple[str, bytes]] = []
        deltas: List[tuple[str, bytes, DeltaRecord]] = []
        for r, b in records.items():
            if is_delta_ref(r):
                h = r[len(DELTA_PREFIX):]
                if sha256(b) != h:
                    raise IOError(f"ingest: delta {r[:14]} hash mismatch")
                deltas.append((h, b, DeltaRecord.unpack(b)))
            else:
                if sha256(b) != r:
                    raise IOError(f"ingest: chunk {r[:14]} hash mismatch")
                raws.append((r, b))
        # validate every chain before anything is written.  A delta's
        # depth is hashed into the record, so a lied depth cannot be
        # repaired, only rejected — accepting it would poison the
        # ``max_chain`` accounting (depth-0 lies disable rebasing, huge
        # ones force every later delta into a full copy).  Each parent
        # must resolve to a known depth: already in this store, a raw
        # chunk in this batch, or an earlier delta in this batch; no
        # progress means a dangling or cyclic chain.
        depth_of = {r: 0 for r, _ in raws}
        todo = {DELTA_PREFIX + h: (h, b, rec) for h, b, rec in deltas}
        ordered: List[tuple[str, bytes, int]] = []
        while todo:
            progressed = False
            for ref, (h, b, rec) in list(todo.items()):
                p = rec.parent
                if self.has(p):
                    want = self.ref_depth(p) + 1
                elif p in depth_of:
                    want = depth_of[p] + 1
                else:
                    continue
                if rec.depth != want:
                    raise IOError(f"ingest: delta d:{h[:12]} claims depth "
                                  f"{rec.depth}, its chain says {want}")
                depth_of[ref] = want
                ordered.append((h, b, want))
                del todo[ref]
                progressed = True
            if not progressed:
                h = next(iter(todo.values()))[0]
                raise IOError(f"ingest: delta d:{h[:12]} has a dangling "
                              f"or cyclic parent chain")
        written = 0
        for r, b in raws:
            if not self.has(r):
                written += len(b)
            self.put(b)
        for h, b, depth in ordered:
            if not self.has(DELTA_PREFIX + h):
                written += len(b)
            self._write_delta(h, b, depth)
        self.metrics.ingest_bytes.inc(written)
        self.metrics.ingest_records.inc(len(records))
        if self.tel.tracing:
            self.tel.event("ingest", records=len(records), bytes=written,
                           client=client_id)
        if client_id is not None:
            log = self._client_log(client_id)
            log["records"] += len(records)
            log["bytes_in"] += written    # verified bytes, not the claim
        return written

    # -- deprecated pre-Wire names (thin shims) ----------------------------
    def transfer_plan(self, refs: Iterable[str],
                      client_has: set[str]) -> TransferPlan:
        """Deprecated: use ``plan_send``."""
        _warn_wire("ChunkStore.transfer_plan", "plan_send")
        return self.plan_send(refs, client_has)

    def ingest_plan(self, offered: Dict[str, int], *,
                    client_id: Optional[str] = None) -> TransferPlan:
        """Deprecated: use ``plan_recv``."""
        _warn_wire("ChunkStore.ingest_plan", "plan_recv")
        return self.plan_recv(offered, client_id=client_id)

    def export_records(self, refs: Iterable[str]) -> Dict[str, bytes]:
        """Deprecated: use ``send``."""
        _warn_wire("ChunkStore.export_records", "send")
        return self.send(refs)

    def ingest(self, records: Dict[str, bytes], *,
               client_id: Optional[str] = None) -> int:
        """Deprecated: use ``recv``."""
        _warn_wire("ChunkStore.ingest", "recv")
        return self.recv(records, client_id=client_id)

    def wipe(self) -> None:
        """Simulated disk loss: drop every object (fault injection — the
        churn simulator's "the volunteer's disk died" event)."""
        if self.tel.tracing:
            self.tel.event("wipe")
        with self._lock:
            self._mem.clear()
            self._mem_delta.clear()
            self._depths.clear()
            if self.root is not None:
                for sub in ("objects", "deltas"):
                    shutil.rmtree(self.root / sub, ignore_errors=True)
                    (self.root / sub).mkdir(parents=True, exist_ok=True)

    def sweep_tmp(self, max_age_s: float = 60.0) -> int:
        """Unlink ``*.tmp`` orphans left by crashed writers.  Only files
        older than ``max_age_s`` go — a concurrent writer's in-flight temp
        file (same directory, about to ``os.replace``) is never touched."""
        if self.root is None:
            return 0
        now = time.time()
        removed = 0
        for sub in ("objects", "deltas"):
            for p in (self.root / sub).glob("*/*.tmp"):
                try:
                    if now - p.stat().st_mtime >= max_age_s:
                        p.unlink()
                        removed += 1
                except OSError:
                    continue                 # raced a writer/another sweep
        return removed

    def gc(self, live: set[str]) -> int:
        """Delete all objects not in the closure of ``live``; returns count
        removed.  (The closure keeps delta parents alive.)

        Mark + sweep run under ``gc_lock``: an async snapshot write holds
        the same lock across "put objects + register manifest", so the
        sweep can never observe (and delete) a half-committed snapshot's
        objects.  Callers that assemble ``live`` from several managers must
        collect it under the same lock (it is reentrant)."""
        with self.gc_lock:
            keep = self.live_closure(live)
            dead = [r for r in self.all_refs() if r not in keep]
            for r in dead:
                self.delete(r)
            self.sweep_tmp()
            return len(dead)


@dataclass
class StoreStats:
    put_bytes: int = 0
    dedup_bytes: int = 0
    chunks: int = 0
    extra: dict = field(default_factory=dict)
