"""Content-addressed chunk store — the substrate for differencing snapshots.

VirtualBox differencing images store "all write operations after a snapshot";
our analogue chunks every tensor into fixed-size blocks, keyed by SHA-256.
A snapshot manifest is a list of chunk hashes per tensor; a *differencing*
snapshot re-uses every unchanged chunk of its parent for free (same hash →
same object), so its incremental cost is exactly the written-to blocks —
the paper's Table II behaviour (CPU-bound workloads → ~zero snapshot size,
memory/disk-heavy → large) falls out by construction.

The store backend is a directory of hash-named objects (or in-memory for
tests).  Integrity = re-hash on read (the paper's "trusted application"
concern: a volunteer can verify every byte it receives).
"""
from __future__ import annotations

import hashlib
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Optional

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB


def sha256(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


class ChunkStore:
    """Deduplicating object store with refcount GC."""

    def __init__(self, root: Optional[os.PathLike] = None,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        self.chunk_bytes = int(chunk_bytes)
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            (self.root / "objects").mkdir(parents=True, exist_ok=True)
        self._mem: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.stats = {"put_bytes": 0, "dedup_bytes": 0, "get_bytes": 0,
                      "put_chunks": 0, "dedup_chunks": 0}

    # -- object layer ------------------------------------------------------
    def _path(self, h: str) -> Path:
        return self.root / "objects" / h[:2] / h[2:]

    def has(self, h: str) -> bool:
        if self.root is None:
            return h in self._mem
        return h in self._mem or self._path(h).exists()

    def put(self, data: bytes) -> str:
        h = sha256(data)
        with self._lock:
            if self.has(h):
                self.stats["dedup_bytes"] += len(data)
                self.stats["dedup_chunks"] += 1
                return h
            self.stats["put_bytes"] += len(data)
            self.stats["put_chunks"] += 1
            if self.root is None:
                self._mem[h] = bytes(data)
            else:
                p = self._path(h)
                p.parent.mkdir(parents=True, exist_ok=True)
                tmp = p.with_suffix(".tmp")
                tmp.write_bytes(data)
                os.replace(tmp, p)  # atomic publish
        return h

    def get(self, h: str) -> bytes:
        if self.root is None or h in self._mem:
            data = self._mem[h]
        else:
            data = self._path(h).read_bytes()
        if sha256(data) != h:  # integrity (sandbox/trust analogue)
            raise IOError(f"chunk {h[:12]} failed integrity check")
        self.stats["get_bytes"] += len(data)
        return data

    def delete(self, h: str) -> None:
        with self._lock:
            self._mem.pop(h, None)
            if self.root is not None:
                p = self._path(h)
                if p.exists():
                    p.unlink()

    def all_hashes(self) -> Iterable[str]:
        out = set(self._mem)
        if self.root is not None:
            for sub in (self.root / "objects").glob("*/*"):
                out.add(sub.parent.name + sub.name)
        return out

    # -- tensor layer ------------------------------------------------------
    def put_buffer(self, buf: memoryview) -> list[str]:
        """Chunk + store one tensor's bytes; returns the hash list."""
        buf = memoryview(buf).cast("B")
        return [self.put(bytes(buf[o:o + self.chunk_bytes]))
                for o in range(0, max(len(buf), 1), self.chunk_bytes)]

    def get_buffer(self, hashes: list[str]) -> bytes:
        return b"".join(self.get(h) for h in hashes)

    def gc(self, live: set[str]) -> int:
        """Delete all objects not in ``live``; returns count removed."""
        dead = [h for h in self.all_hashes() if h not in live]
        for h in dead:
            self.delete(h)
        return len(dead)


@dataclass
class StoreStats:
    put_bytes: int = 0
    dedup_bytes: int = 0
    chunks: int = 0
    extra: dict = field(default_factory=dict)
