"""BaseDisk / DepDisk state partitioning (paper §III-C).

V-BOINC splits the VM over two VDI files: a minimal *fixed-size* base image
(FDI) and growable *dependency disks* (DDI) that are attached per project, so
switching projects only swaps the DepDisk.  Our analogue partitions training
state into namespaces with independent manifests and lifecycle:

* ``base``  — model parameters: fixed layout, content-addressed, shared by
  every task fine-tuning the same model (the "649 MB FDI").
* DepDisks  — optimizer state, task adapters (LoRA), KV caches: created
  empty ("fresh disk locally created"), grow chunk-on-write, attach/detach
  without touching the base.

Snapshot sizes are reported per-disk, reproducing Table II's separate
"DepDisk Snapshot Size" / "VM Snapshot Size" columns.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.chunkstore import ChunkStore
from repro.core.snapshots import Manifest, SnapshotInfo, SnapshotManager


@dataclass
class DiskInfo:
    name: str
    kind: str                   # base (FDI) | dep (DDI)
    attached: bool
    snapshots: int
    logical_bytes: int


class DiskSet:
    """A capsule's attached storage: one base disk + N dependency disks."""

    def __init__(self, store: ChunkStore, root=None, keep_last: int = 3,
                 async_mode: bool = False, writer_depth: int = 2,
                 delta_mode: str = "auto"):
        self.store = store
        self._managers: Dict[str, SnapshotManager] = {}
        self._kinds: Dict[str, str] = {}
        self._attached: Dict[str, bool] = {}
        self._root = root
        self._keep_last = keep_last
        self._async_mode = async_mode
        self._writer_depth = writer_depth
        self._delta_mode = delta_mode

    # ------------------------------------------------------------------
    def _mgr(self, name: str) -> SnapshotManager:
        if name not in self._managers:
            sub = None if self._root is None else self._root / name
            # auto_gc off: the store is shared across disks, so only the
            # DiskSet-level mark (gc_all) may sweep it.
            self._managers[name] = SnapshotManager(
                self.store, root=sub, keep_last=self._keep_last,
                auto_gc=False, async_mode=self._async_mode,
                writer_depth=self._writer_depth,
                delta_mode=self._delta_mode)
        return self._managers[name]

    def create_base(self, params, *, step: int = 0) -> SnapshotInfo:
        """Register the fixed base image (model params)."""
        self._kinds["base"] = "base"
        self._attached["base"] = True
        return self._mgr("base").snapshot(params, step=step)

    def attach_dep(self, name: str, state: Any = None, *,
                   step: int = 0) -> Optional[SnapshotInfo]:
        """Attach a DepDisk; fresh (empty) if no state is given."""
        if name == "base":
            raise ValueError("'base' is reserved")
        self._kinds[name] = "dep"
        self._attached[name] = True
        if state is not None:
            return self._mgr(name).snapshot(state, step=step)
        return None

    def detach(self, name: str) -> None:
        """Detach (keeps snapshots — a re-attach later resumes the task)."""
        if not self._attached.get(name):
            raise KeyError(f"disk {name!r} not attached")
        self._attached[name] = False

    def snapshot_disk(self, name: str, state, *, step: int,
                      aux: Optional[dict] = None, block: bool = True):
        if not self._attached.get(name):
            raise KeyError(f"disk {name!r} not attached")
        res = self._mgr(name).snapshot(state, step=step, aux=aux,
                                       block=block)
        if block:
            self.gc_all()
        # non-blocking (async writer): sweeping here would stall the caller
        # on the gc lock the writer holds mid-commit — callers run
        # wait_all() + gc_all() off the hot path instead
        return res

    def wait_all(self) -> None:
        """Drain every disk's pending background writes."""
        for mgr in self._managers.values():
            mgr.wait()

    def close_all(self) -> None:
        for mgr in self._managers.values():
            mgr.close()

    def restore_disk(self, name: str, *, target_tree=None, shardings=None,
                     snapshot_id: Optional[str] = None):
        return self._mgr(name).restore(snapshot_id, target_tree=target_tree,
                                       shardings=shardings)

    def swap_task(self, old: str, new: str, state: Any = None):
        """Switch projects: detach one DepDisk, attach another — the base
        disk is untouched (no re-download of the 'VM image')."""
        if self._attached.get(old):
            self.detach(old)
        return self.attach_dep(new, state)

    # ------------------------------------------------------------------
    def disks(self) -> list[DiskInfo]:
        out = []
        for name, kind in self._kinds.items():
            mgr = self._managers.get(name)
            latest = mgr.manifests.get(mgr.latest()) if mgr and mgr.latest() \
                else None
            logical = 0
            if latest is not None:
                for ent in latest.tensors.values():
                    import numpy as np
                    n = 1
                    for d in ent.shape:
                        n *= d
                    logical += n * np.dtype(ent.dtype).itemsize
            out.append(DiskInfo(name, kind, self._attached.get(name, False),
                                len(mgr.order) if mgr else 0, logical))
        return out

    def gc_all(self) -> int:
        """Mark live refs across ALL disks (the store expands the closure
        over delta parents), sweep the shared store.

        Live-set collection and the sweep hold the store's ``gc_lock``
        together: with async writers a sibling disk's snapshot could
        commit between an unlocked mark and the sweep, and its
        just-written objects — absent from the stale live set — would be
        swept.  The lock is reentrant, so ``store.gc`` re-acquiring it
        inside is fine."""
        with self.store.gc_lock:
            live: set[str] = set()
            for mgr in self._managers.values():
                for man in mgr.manifests.values():
                    live.update(man.all_refs())
            return self.store.gc(live)
