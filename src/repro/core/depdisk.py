"""BaseDisk / DepDisk state partitioning (paper §III-C).

V-BOINC splits the VM over two VDI files: a minimal *fixed-size* base image
(FDI) and growable *dependency disks* (DDI) that are attached per project, so
switching projects only swaps the DepDisk.  Our analogue partitions training
state into namespaces with independent manifests and lifecycle:

* ``base``  — model parameters: fixed layout, content-addressed, shared by
  every task fine-tuning the same model (the "649 MB FDI").
* DepDisks  — optimizer state, task adapters (LoRA), KV caches: created
  empty ("fresh disk locally created"), grow chunk-on-write, attach/detach
  without touching the base.

Snapshot sizes are reported per-disk, reproducing Table II's separate
"DepDisk Snapshot Size" / "VM Snapshot Size" columns.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.chunkstore import ChunkStore
from repro.core.snapshots import Manifest, SnapshotInfo, SnapshotManager


@dataclass
class DiskInfo:
    name: str
    kind: str                   # base (FDI) | dep (DDI)
    attached: bool
    snapshots: int
    logical_bytes: int


class DiskSet:
    """A capsule's attached storage: one base disk + N dependency disks."""

    def __init__(self, store: ChunkStore, root=None, keep_last: int = 3):
        self.store = store
        self._managers: Dict[str, SnapshotManager] = {}
        self._kinds: Dict[str, str] = {}
        self._attached: Dict[str, bool] = {}
        self._root = root
        self._keep_last = keep_last

    # ------------------------------------------------------------------
    def _mgr(self, name: str) -> SnapshotManager:
        if name not in self._managers:
            sub = None if self._root is None else self._root / name
            # auto_gc off: the store is shared across disks, so only the
            # DiskSet-level mark (gc_all) may sweep it.
            self._managers[name] = SnapshotManager(
                self.store, root=sub, keep_last=self._keep_last,
                auto_gc=False)
        return self._managers[name]

    def create_base(self, params, *, step: int = 0) -> SnapshotInfo:
        """Register the fixed base image (model params)."""
        self._kinds["base"] = "base"
        self._attached["base"] = True
        return self._mgr("base").snapshot(params, step=step)

    def attach_dep(self, name: str, state: Any = None, *,
                   step: int = 0) -> Optional[SnapshotInfo]:
        """Attach a DepDisk; fresh (empty) if no state is given."""
        if name == "base":
            raise ValueError("'base' is reserved")
        self._kinds[name] = "dep"
        self._attached[name] = True
        if state is not None:
            return self._mgr(name).snapshot(state, step=step)
        return None

    def detach(self, name: str) -> None:
        """Detach (keeps snapshots — a re-attach later resumes the task)."""
        if not self._attached.get(name):
            raise KeyError(f"disk {name!r} not attached")
        self._attached[name] = False

    def snapshot_disk(self, name: str, state, *, step: int,
                      aux: Optional[dict] = None) -> SnapshotInfo:
        if not self._attached.get(name):
            raise KeyError(f"disk {name!r} not attached")
        info = self._mgr(name).snapshot(state, step=step, aux=aux)
        self.gc_all()
        return info

    def restore_disk(self, name: str, *, target_tree=None, shardings=None,
                     snapshot_id: Optional[str] = None):
        return self._mgr(name).restore(snapshot_id, target_tree=target_tree,
                                       shardings=shardings)

    def swap_task(self, old: str, new: str, state: Any = None):
        """Switch projects: detach one DepDisk, attach another — the base
        disk is untouched (no re-download of the 'VM image')."""
        if self._attached.get(old):
            self.detach(old)
        return self.attach_dep(new, state)

    # ------------------------------------------------------------------
    def disks(self) -> list[DiskInfo]:
        out = []
        for name, kind in self._kinds.items():
            mgr = self._managers.get(name)
            latest = mgr.manifests.get(mgr.latest()) if mgr and mgr.latest() \
                else None
            logical = 0
            if latest is not None:
                for ent in latest.tensors.values():
                    import numpy as np
                    n = 1
                    for d in ent.shape:
                        n *= d
                    logical += n * np.dtype(ent.dtype).itemsize
            out.append(DiskInfo(name, kind, self._attached.get(name, False),
                                len(mgr.order) if mgr else 0, logical))
        return out

    def gc_all(self) -> int:
        """Mark live refs across ALL disks (the store expands the closure
        over delta parents), sweep the shared store."""
        live: set[str] = set()
        for mgr in self._managers.values():
            for man in mgr.manifests.values():
                live.update(man.all_refs())
        return self.store.gc(live)
