"""Shared membership verbs for replicated member sets.

``ReplicaSet`` (read-write snapshot replicas) and the edge-cache tier
(``core/edge.py``, read-only capsule caches) both manage a list of
members with liveness state that the churn simulator kills, revives,
permanently removes and promotes.  Before this mixin each class carried
its own copy of those verbs with slightly different index bookkeeping;
now one implementation owns the list/liveness invariants (index remap on
``remove``, down-member promotion refusal, primary protection) and the
per-class behaviour — parked outbox refs, cache invalidation, telemetry
events — hangs off the ``_on_*`` hooks.  ``ChurnSim`` drives every
member set through this one interface.
"""
from __future__ import annotations

from typing import Iterable, List


class Membership:
    """Liveness + membership verbs over ``self.members``.

    Subclass contract: call ``_init_membership(members)`` during
    ``__init__`` and override the ``_on_down`` / ``_on_up`` /
    ``_on_remove`` / ``_on_promote`` hooks for class-specific
    bookkeeping.  ``primary_index`` is the distinguished member — the
    write target for a ``ReplicaSet``, the preferred ranking tie-break
    for the edge tier; ``promote`` moves it and ``remove`` refuses to
    drop it (promote a survivor first).
    """

    def _init_membership(self, members: Iterable,
                         primary_index: int = 0) -> None:
        self.members: List = list(members)
        self.primary_index = primary_index
        self._down: set[int] = set()

    # -- hooks (default: no-op) --------------------------------------------
    def _on_down(self, index: int) -> None:
        pass

    def _on_up(self, index: int) -> None:
        pass

    def _on_remove(self, index: int) -> None:
        """Called after the member left and ``_down``/``primary_index``
        were remapped; ``index`` is the member's *pre-removal* slot."""

    def _on_promote(self, index: int) -> None:
        pass

    # -- queries -----------------------------------------------------------
    def is_down(self, index: int) -> bool:
        return index in self._down

    def alive_indices(self) -> List[int]:
        return [i for i in range(len(self.members)) if i not in self._down]

    def _check_index(self, index: int) -> None:
        if not 0 <= index < len(self.members):
            raise IndexError(f"no member {index}")

    # -- verbs -------------------------------------------------------------
    def mark_down(self, index: int) -> None:
        """Mark a member unreachable (it stays in the set and may revive)."""
        self._check_index(index)
        self._down.add(index)
        self._on_down(index)

    def mark_up(self, index: int) -> None:
        """Bring a member back into rotation."""
        self._check_index(index)
        self._down.discard(index)
        self._on_up(index)

    def remove(self, index: int) -> None:
        """Permanently drop a member (a host that will never return).
        The primary cannot be removed — promote a survivor first."""
        self._check_index(index)
        if index == self.primary_index:
            raise ValueError("cannot remove the primary; promote first")
        del self.members[index]
        self._down = {i - (i > index) for i in self._down if i != index}
        if self.primary_index > index:
            self.primary_index -= 1
        self._on_remove(index)

    def promote(self, index: int) -> None:
        """Redesignate an alive member as the distinguished one
        (failover for a replica set, preferred cache for the edge tier)."""
        self._check_index(index)
        if index in self._down:
            raise ValueError(f"cannot promote member {index}: marked down")
        if index != self.primary_index:
            self.primary_index = index
            self._on_promote(index)
