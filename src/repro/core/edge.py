"""Edge capsule distribution: discovery service + delta-cache tier.

The paper's V-BOINC server is the sole distribution point: every volunteer
downloads its capsule (207 MB compressed image) straight from the project
server, so primary egress grows linearly with volunteer count — the exact
server-bandwidth bottleneck Anderson & Fedak quantify and that BOINC's
tiered mirrors address in production.  The delta ChunkStore (PR 1) already
shrank *what* moves; this layer changes *where it moves from*.

Two pieces, one subsystem:

* **Discovery** — a volunteer (or the server routing on its behalf) asks
  ``EdgeTier.discover(refs)``: "who can serve ref closure X?"  The answer
  is a ranked list of alive caches ordered by closure coverage (desc),
  load (fetches served, asc), simulated RTT (asc), with the *preferred*
  cache (``primary_index``, movable via the shared ``Membership.promote``)
  breaking ties.  Every ranking input is deterministic — RTT derives from
  the cache id's sha256, load from the serve count — so two same-seed
  churn schedules pick byte-identical routes.
* **Edge caches** — read-only ``ReplicaSet``-style members.  A cache holds
  a private ChunkStore plus an LRU keyed by *closure* (the chain-expanded
  ref set of one fetch): eviction drops whole closures and sweeps with the
  store's closure-marking GC, so a cache can never serve a torn delta
  chain.  On a miss the best-ranked cache **demand-fills** over the same
  ``Wire`` protocol volunteers speak (``plan_send`` → ``send`` → ``recv``
  — every record re-hashed on arrival), then serves; ``prefetch`` pushes
  hot base chunks to every alive cache ahead of a release wave.  Caches
  earn scheduler ``credit_transfer`` for the bytes they serve, exactly
  like a volunteer earns for uplink bytes — BOINC's credit economy
  extended to distribution.

Liveness churn (kill / revive / stale-revive) arrives through the shared
``Membership`` verbs, driven by ``ChurnSim`` — the same interface that
kills replicas and scheduler shards.  A killed cache drops out of
``discover`` immediately; a stale revive (``invalidate``) empties the
cache so it demand-fills before serving again.

Telemetry: the ``edge`` scope counts hits/misses/fills/evictions and
splits egress by origin vs cache; with tracing on, every routed fetch
emits a ``fetch_route`` event naming the serving member.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core import telemetry as tlm
from repro.core.chunkstore import ChunkStore, is_delta_ref
from repro.core.membership import Membership

DEFAULT_CACHE_CAPACITY = 1 << 28            # 256 MiB per cache


def closure_key(refs: Iterable[str]) -> str:
    """Stable identity of one fetch's ref closure (sha256 of sorted refs)."""
    h = hashlib.sha256()
    for r in sorted(set(refs)):
        h.update(r.encode())
        h.update(b"\0")
    return h.hexdigest()


def simulated_rtt_ms(cache_id: str) -> int:
    """Deterministic per-cache RTT in [5, 55) ms, derived from the id.

    A hash, not a random draw: discovery rankings must be byte-identical
    across runs regardless of any RNG state."""
    return int(hashlib.sha256(cache_id.encode()).hexdigest()[:4], 16) % 50 + 5


class EdgeCache:
    """One read-only edge member: private store + LRU-by-closure eviction.

    The cache never takes volunteer writes — it is filled exclusively from
    the origin over the Wire protocol (``fill_from``), and everything it
    serves was therefore re-hashed on the way in.  Eviction operates on
    whole closures: a closure is admitted or dropped atomically, and the
    sweep is the store's own closure-marking GC over the union of resident
    closures, so a delta record can never outlive its parent here.
    """

    def __init__(self, cache_id: str, store: Optional[ChunkStore] = None, *,
                 capacity_bytes: int = DEFAULT_CACHE_CAPACITY):
        self.cache_id = cache_id
        self.store = store if store is not None else ChunkStore()
        self.capacity_bytes = int(capacity_bytes)
        self.rtt_ms = simulated_rtt_ms(cache_id)
        self.served_fetches = 0                  # the load signal
        # closure key -> (refs tuple, resident bytes); order = LRU
        self._lru: "OrderedDict[str, Tuple[Tuple[str, ...], int]]" = \
            OrderedDict()
        self._metrics = None                     # set by EdgeTier

    # -- queries -----------------------------------------------------------
    def resident_bytes(self) -> int:
        return sum(n for _, n in self._lru.values())

    def resident_refs(self) -> set[str]:
        return {r for refs, _ in self._lru.values() for r in refs}

    def coverage(self, refs: List[str]) -> float:
        """Fraction of ``refs`` this cache holds (1.0 = can serve now)."""
        if not refs:
            return 1.0
        have = sum(1 for r in refs if self.store.has(r))
        return have / len(refs)

    def can_serve(self, refs: List[str]) -> bool:
        return self.coverage(refs) >= 1.0

    # -- fill / serve ------------------------------------------------------
    def fill_from(self, origin: ChunkStore, refs: List[str]) -> int:
        """Demand-fill the closure of ``refs`` from ``origin`` over the
        Wire protocol; returns bytes moved (origin egress).  Records are
        re-hashed by ``recv`` — a corrupt origin cannot poison the tier."""
        plan = origin.plan_send(refs, self.resident_refs())
        moved = 0
        if plan.refs:
            records = origin.send(plan.refs)
            self.store.recv(records)
            moved = sum(len(b) for b in records.values())
        self._admit(origin.live_closure(refs))
        return moved

    def serve(self, refs: List[str]) -> Dict[str, bytes]:
        """Pack ``refs`` for a volunteer (cache egress, counts as load).

        Recency is keyed by *resident* closures, not the request's live
        closure: a subset fetch (or a request closed after a later fill)
        rarely hashes to any admitted closure key, so keying the touch by
        the request left hot closures looking cold to the LRU.  Touch
        every admitted closure the served refs intersect instead."""
        served = self.store.live_closure(refs)
        touched = [k for k, (crefs, _) in self._lru.items()
                   if not served.isdisjoint(crefs)]
        for k in touched:
            self._lru.move_to_end(k)
        self.served_fetches += 1
        return self.store.send(refs)

    def invalidate(self) -> None:
        """Stale revive: drop everything; the cache must demand-fill
        before it can serve again."""
        self._lru.clear()
        self.store.wipe()

    # -- eviction ----------------------------------------------------------
    def _admit(self, closure: set[str]) -> None:
        nbytes = sum(self.store.object_size(r) for r in closure
                     if self.store.has(r))
        key = closure_key(closure)
        if key in self._lru:
            self._lru.move_to_end(key)
        self._lru[key] = (tuple(sorted(closure)), nbytes)
        while (self.resident_bytes() > self.capacity_bytes
               and len(self._lru) > 1):
            self._lru.popitem(last=False)        # whole closures only
            if self._metrics is not None:
                self._metrics.evictions.inc()
        # sweep: anything outside the surviving closures leaves the store
        self.store.gc(self.resident_refs())


@dataclass
class FetchResult:
    """One routed fetch: the plan plus where the bytes came from."""
    missing: List[str]
    bytes_moved: int
    bytes_dedup: int
    route: str                       # "dedup", "origin", or a cache id
    records: Dict[str, bytes] = field(default_factory=dict)

    def _astuple(self):
        # legacy (missing, moved, dedup) unpacking, like TransferPlan
        return (self.missing, self.bytes_moved, self.bytes_dedup)

    def __iter__(self):
        return iter(self._astuple())

    def __len__(self):
        return 3

    def __getitem__(self, i):
        return self._astuple()[i]


class EdgeTier(Membership):
    """Discovery + routing over a set of edge caches in front of one origin.

    ``members`` are :class:`EdgeCache` instances sharing the
    :class:`Membership` liveness verbs with ``ReplicaSet`` — ``ChurnSim``
    kills, revives and promotes caches through the exact interface it
    drives replicas with.  ``primary_index`` is the *preferred* cache (the
    discovery tie-break), not a write target: the tier is read-only and
    the origin remains the single source of truth.
    """

    def __init__(self, origin: ChunkStore,
                 caches: Iterable[EdgeCache] = (), *,
                 scheduler=None,
                 telemetry: Optional[tlm.Telemetry] = None):
        self.origin = origin
        self.scheduler = scheduler
        self._init_membership(list(caches))
        self.tel = tlm.resolve(telemetry)
        scope = self.tel.scope("edge")
        self.metrics = scope.counters(
            "fetches", "hits", "misses", "fills", "fill_bytes",
            "prefetch_bytes", "origin_egress_bytes", "cache_egress_bytes",
            "evictions")
        self.stats = scope.view()
        for c in self.members:
            c._metrics = self.metrics
        if scheduler is not None:
            for c in self.members:
                scheduler.join(c.cache_id)

    # -- membership hooks --------------------------------------------------
    def _on_down(self, index: int) -> None:
        if self.tel.tracing:
            self.tel.event("cache_down", cache=self.members[index].cache_id)

    def _on_up(self, index: int) -> None:
        if self.tel.tracing:
            self.tel.event("cache_up", cache=self.members[index].cache_id)

    def _on_promote(self, index: int) -> None:
        if self.tel.tracing:
            self.tel.event("cache_preferred",
                           cache=self.members[index].cache_id)

    # -- discovery ---------------------------------------------------------
    def discover(self, refs: List[str]) -> List[Tuple[int, EdgeCache]]:
        """Rank alive caches for serving ``refs``.

        Order: coverage desc, load (fetches served) asc, simulated RTT
        asc, preferred-cache tie-break, index.  A killed cache does not
        appear at all.  Every key is deterministic, so equal histories
        rank identically."""
        ranked = []
        for i in self.alive_indices():
            c = self.members[i]
            ranked.append((-c.coverage(refs), c.served_fetches, c.rtt_ms,
                           0 if i == self.primary_index else 1, i, c))
        ranked.sort(key=lambda t: t[:5])
        return [(t[4], t[5]) for t in ranked]

    # -- routing -----------------------------------------------------------
    def fetch(self, refs: List[str], client_has: Optional[set] = None, *,
              client_store: Optional[ChunkStore] = None) -> FetchResult:
        """Route one volunteer fetch through discovery.

        The transfer accounting (missing refs, bytes moved, bytes saved)
        is the origin's ``plan_send`` — identical to the no-edge path, so
        a restore is byte-for-byte the same no matter who served it; only
        *whose* egress meter runs differs.  With ``client_store`` the
        packed records are actually delivered (and re-hashed) there."""
        plan = self.origin.plan_send(refs, client_has or set())
        self.metrics.fetches.inc()
        if not plan.refs:
            self._trace_route("dedup", plan)
            return FetchResult(plan.refs, plan.bytes_moved,
                               plan.bytes_dedup, "dedup")
        ranked = self.discover(plan.refs)
        if not ranked:
            records = self.origin.send(plan.refs)
            self.metrics.misses.inc()
            self.metrics.origin_egress_bytes.inc(plan.bytes_moved)
            route = "origin"
        else:
            index, cache = ranked[0]
            filled = 0
            if not cache.can_serve(plan.refs):
                self.metrics.misses.inc()
                self.metrics.fills.inc()
                filled = cache.fill_from(self.origin, plan.refs)
                self.metrics.fill_bytes.inc(filled)
                self.metrics.origin_egress_bytes.inc(filled)
            else:
                self.metrics.hits.inc()
            records = cache.serve(plan.refs)
            self.metrics.cache_egress_bytes.inc(plan.bytes_moved)
            # credit settles only on bytes the cache served from
            # already-resident closures: on a demand-fill miss the origin
            # just moved ``filled`` of plan.bytes_moved itself (it is on
            # the origin_egress meter), so minting transfer credit for
            # the full plan double-paid every cold fetch
            resident = max(0, plan.bytes_moved - filled)
            if self.scheduler is not None and resident > 0:
                self.scheduler.credit_transfer(cache.cache_id, resident)
            route = cache.cache_id
        if client_store is not None:
            client_store.recv(records)
        self._trace_route(route, plan)
        return FetchResult(plan.refs, plan.bytes_moved, plan.bytes_dedup,
                           route, records)

    def _trace_route(self, route: str, plan) -> None:
        if self.tel.tracing:
            self.tel.event("fetch_route", route=route, refs=len(plan.refs),
                           bytes=plan.bytes_moved)

    # -- prefetch ----------------------------------------------------------
    def prefetch(self, refs: List[str], *, base_only: bool = True) -> int:
        """Warm every alive cache with (the closure of) ``refs`` ahead of a
        release wave.  ``base_only`` keeps only raw chunks — the shared
        capsule base every volunteer needs — and leaves per-volunteer delta
        chains to demand-fill.  Returns total bytes pushed."""
        want = [r for r in refs if not (base_only and is_delta_ref(r))]
        if not want:
            return 0
        total = 0
        for i in self.alive_indices():
            moved = self.members[i].fill_from(self.origin, want)
            total += moved
        self.metrics.prefetch_bytes.inc(total)
        self.metrics.origin_egress_bytes.inc(total)
        return total

    # -- introspection -----------------------------------------------------
    def cache_ids(self) -> List[str]:
        return [c.cache_id for c in self.members]

    def describe(self) -> List[dict]:
        """Deterministic per-cache summary (benchmarks/tests)."""
        return [{"cache_id": c.cache_id,
                 "alive": i not in self._down,
                 "resident_bytes": c.resident_bytes(),
                 "closures": len(c._lru),
                 "served_fetches": c.served_fetches,
                 "rtt_ms": c.rtt_ms}
                for i, c in enumerate(self.members)]
