"""Background snapshot store writer — the zero-stall half of a snapshot.

The snapshot hot path used to pay for chunk hashing, zero-run RLE, store
writes and ``max_chain`` rebase compaction inline; the trainer stalled for
all of it.  ``SnapshotWriter`` moves that work to one background thread
behind a bounded queue:

* **Double buffering** — the trainer plans snapshot N+1 (device probe +
  changed-tile transfer) while the writer persists snapshot N.  Plans are
  self-contained (they carry the changed chunks' XOR *and* full bytes), so
  the writer never reads the planner's mirror — no shared mutable state
  between the two threads beyond the queue.
* **Backpressure** — the queue is bounded (``depth``); when the writer
  falls behind, ``submit`` blocks and the blocked time is accounted as
  ``backpressure_ms`` (it is trainer-visible stall, not hidden).
* **Fail-stop** — a failed write poisons the writer: every queued and
  later submission fails fast with the original error chained, because a
  write after a failed write would record delta refs against parents that
  were never persisted.  The owner observes the failure (via the returned
  future), re-bases its mirror, and calls ``reset``.

Crash consistency is the manager's invariant, unchanged: a manifest is
registered only after every object write lands, so a half-written snapshot
is invisible and the store never serves a torn committed snapshot.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional

from repro.core import telemetry as tlm

_STOP = object()


class WriterPoisonedError(RuntimeError):
    """A snapshot write was refused because an earlier write failed."""


class SnapshotWriter:
    def __init__(self, write_fn: Callable, depth: int = 2, *,
                 telemetry: Optional[tlm.Telemetry] = None):
        if depth < 1:
            raise ValueError("writer depth must be >= 1")
        self.write_fn = write_fn
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self.error: Optional[BaseException] = None
        self.tel = tlm.resolve(telemetry)
        scope = self.tel.scope("writer")
        self.metrics = scope.counters("submitted", "written", "failed")
        # ms accumulators are float-valued counters; same dict keys as ever
        self.metrics.backpressure_ms = scope.counter("backpressure_ms", 0.0)
        self.metrics.write_ms = scope.counter("write_ms", 0.0)
        self.stats = scope.view()
        self._thread = threading.Thread(
            target=self._loop, name="snapshot-writer", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def submit(self, *args) -> Future:
        """Enqueue one write; blocks only when the bounded queue is full
        (counted as ``backpressure_ms`` — real trainer-visible stall)."""
        if self.error is not None:
            raise WriterPoisonedError(
                "snapshot writer poisoned by an earlier failure"
            ) from self.error
        fut: Future = Future()
        t0 = time.perf_counter()
        self._q.put((fut, args))
        self.metrics.backpressure_ms.inc((time.perf_counter() - t0) * 1e3)
        self.metrics.submitted.inc()
        return fut

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                return
            fut, args = item
            if self.error is not None:
                # fail-stop: later writes would chain refs onto parents
                # that never landed
                fut.set_exception(WriterPoisonedError(
                    "snapshot writer poisoned by an earlier failure"))
                continue
            t0 = time.perf_counter()
            try:
                res = self.write_fn(*args)
            except BaseException as exc:  # noqa: BLE001 — forwarded via future
                self.error = exc
                self.metrics.failed.inc()
                fut.set_exception(exc)
            else:
                self.metrics.written.inc()
                fut.set_result(res)
            finally:
                self.metrics.write_ms.inc((time.perf_counter() - t0) * 1e3)

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Clear the poison flag after the owner has re-based its state
        (next snapshot must be a full base image)."""
        self.error = None

    def close(self) -> None:
        self._q.put(_STOP)
        self._thread.join(timeout=30.0)
