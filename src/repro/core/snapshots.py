"""System-level checkpointing with device-resident differencing snapshots.

The SnapshotManager checkpoints the ENTIRE program state transparently —
params, optimizer moments, data cursor, RNG, step — so "project developers
omit application-level checkpointing from their code" (paper §III-E).
Mechanics mirror VirtualBox snapshots, but the diff is computed *before*
anything crosses the device→host boundary:

* ``snapshot()`` — the first snapshot is a full base image.  Every later
  one is a *differencing image*: the Pallas ``changed_bitmap`` kernel
  (kernels/delta_encode) XORs the new state against the previous
  snapshot's host mirror per-tensor and emits one flag per 32 KiB tile;
  only the changed tiles are gathered and transferred.  Unchanged store
  chunks re-use the parent manifest's refs with **no hashing at all**, and
  changed chunks are written as delta objects (``parent_ref + RLE XOR``)
  — snapshot cost is O(changed blocks), not O(state bytes).
* **Manifest v2** — each ``TensorEntry`` records per-block refs that are
  either raw hashes or ``"d:"`` delta refs.  v1 manifests (``hashes``)
  remain readable, so old snapshot directories restore unchanged.
* ``restore(sid)`` — resolve each ref through its base chain
  (``ChunkStore.resolve``) and rebuild the pytree; chains are bounded by
  the store's ``max_chain`` (deep chains rebase automatically).
* ``delete/gc`` — mark the *closure* of live refs from retained
  snapshots (a delta keeps its parents alive), sweep the rest.
* async mode — delta planning (device diff + changed-tile transfer)
  happens synchronously (cheap); store writes run on a background thread
  so checkpointing overlaps training compute.

Restore across meshes: manifests record logical tensors (path, shape,
dtype); ``restore`` re-shards onto whatever mesh the caller's shardings
dictate — this is what lets a capsule resume on a *different* volunteer
pod (elastic rescale).
"""
from __future__ import annotations

import json
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.chunkstore import ChunkStore, sha256
from repro.kernels.delta_encode.ops import changed_blocks

MANIFEST_VERSION = 2


def _flatten(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


@dataclass
class TensorEntry:
    shape: tuple
    dtype: str
    refs: List[str]           # per-block: raw sha256 hex | "d:" delta ref

    # v1 manifests named this field "hashes"; keep the alias for callers
    @property
    def hashes(self) -> List[str]:
        return self.refs

    def to_json(self):
        return {"shape": list(self.shape), "dtype": self.dtype,
                "refs": self.refs}

    @classmethod
    def from_json(cls, d):
        return cls(tuple(d["shape"]), d["dtype"],
                   list(d.get("refs", d.get("hashes", []))))


@dataclass
class Manifest:
    snapshot_id: str
    parent: Optional[str]
    step: int
    created: float
    tensors: Dict[str, TensorEntry]
    aux: dict = field(default_factory=dict)      # cursor, rng seed, metrics
    kind: str = "diff"                            # base | diff
    version: int = MANIFEST_VERSION

    def all_refs(self) -> List[str]:
        return [r for ent in self.tensors.values() for r in ent.refs]

    def to_json(self) -> str:
        return json.dumps({
            "version": self.version,
            "snapshot_id": self.snapshot_id, "parent": self.parent,
            "step": self.step, "created": self.created, "kind": self.kind,
            "aux": self.aux,
            "tensors": {k: t.to_json() for k, t in self.tensors.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        d = json.loads(s)
        return cls(d["snapshot_id"], d["parent"], d["step"], d["created"],
                   {k: TensorEntry.from_json(t)
                    for k, t in d["tensors"].items()},
                   d.get("aux", {}), d.get("kind", "diff"),
                   d.get("version", 1))


@dataclass
class SnapshotInfo:
    snapshot_id: str
    step: int
    kind: str
    wall_s: float
    new_bytes: int        # differencing-image cost (changed blocks)
    dedup_bytes: int      # blocks reused from the chain
    total_bytes: int      # logical state size
    changed_chunks: int = 0
    reused_chunks: int = 0


@dataclass
class _TensorPlan:
    """Per-tensor work computed synchronously at snapshot() time."""
    key: str
    shape: tuple
    dtype: str
    nbytes: int
    base: Optional[np.ndarray] = None        # full host image (base path)
    deltas: Dict[int, bytes] = field(default_factory=dict)
    # delta path: chunk index -> xor bytes (full bytes come from the
    # mirror at write time, so the plan holds each changed chunk once)


class SnapshotManager:
    def __init__(self, store: ChunkStore,
                 root: Optional[Path] = None,
                 keep_last: int = 3,
                 async_mode: bool = False,
                 auto_gc: bool = True,
                 delta: bool = True,
                 delta_mode: str = "auto"):
        self.store = store
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        # when the store is SHARED across managers (DiskSet), per-manager
        # sweeps would delete sibling disks' chunks — the owner must run a
        # global mark (DiskSet.gc_all) instead.
        self.auto_gc = auto_gc
        # delta=False falls back to the v1 full-hash path (every snapshot
        # re-hashes every chunk); delta_mode picks the diff backend:
        # "auto" (TPU kernel on TPU, numpy oracle elsewhere), "tpu",
        # "interpret", "ref".
        self.delta = delta
        self.delta_mode = delta_mode
        self.manifests: Dict[str, Manifest] = {}
        self.order: List[str] = []                 # snapshot chain
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._pending: Optional[Future] = None
        self._counter = 0
        self._mirror: Dict[str, np.ndarray] = {}   # host copy of last state
        self._prev_refs: Dict[str, List[str]] = {}

    # ------------------------------------------------------------------
    def snapshot(self, state, *, step: int, aux: Optional[dict] = None,
                 block: bool = True) -> SnapshotInfo | Future:
        """Take a snapshot.  ``state`` is any pytree of arrays.

        Planning (device diff + changed-tile transfer + mirror update) is
        synchronous; store/manifest writes go to the background thread in
        async mode."""
        self.wait()              # delta planning needs the previous refs
        t0 = time.time()
        try:
            plan = [self._plan_tensor(k, v) for k, v in _flatten(state)]
        except BaseException:
            # a partial plan has already advanced some tensors' mirrors
            # while _prev_refs still points at the old chunks; drop both so
            # the next snapshot re-bases instead of recording stale refs
            self._mirror.clear()
            self._prev_refs.clear()
            raise
        if self._pool is not None and not block:
            self._pending = self._pool.submit(
                self._write, plan, step, aux or {}, t0)
            return self._pending
        return self._write(plan, step, aux or {}, t0)

    def wait(self) -> Optional[SnapshotInfo]:
        if self._pending is not None:
            fut, self._pending = self._pending, None   # raise at most once
            return fut.result()
        return None

    # ------------------------------------------------------------------
    def _plan_tensor(self, key: str, leaf) -> _TensorPlan:
        if not hasattr(leaf, "dtype"):
            leaf = np.asarray(leaf)
        shape = tuple(leaf.shape)
        dtype = str(leaf.dtype)
        cb = self.store.chunk_bytes
        prev = self._mirror.get(key)
        usable = (self.delta and prev is not None
                  and prev.shape == shape and str(prev.dtype) == dtype
                  and key in self._prev_refs)
        if not usable:
            host = np.ascontiguousarray(np.asarray(leaf))
            if host.shape != shape:
                host = host.reshape(shape)   # ascontiguousarray 0-d -> 1-d
            if host is leaf or host.base is not None:
                host = host.copy()       # mirror must not alias caller data
            self._mirror[key] = host
            return _TensorPlan(key, shape, dtype, host.nbytes, base=host)

        # delta path: device-side probe, transfer only changed tiles; the
        # upload mode emits store-ready per-chunk XOR records (the same
        # records the volunteer uplink encoder pushes through ingest)
        records, new_flat, nbytes = changed_blocks(
            prev, leaf, mode=self.delta_mode, emit="records", chunk_bytes=cb)
        plan = _TensorPlan(key, shape, dtype, nbytes)
        if not records:
            return plan                  # nothing moved, nothing to store
        plan.deltas = records
        self._mirror[key] = new_flat.view(prev.dtype).reshape(shape)
        return plan

    def _write(self, plan: List[_TensorPlan], step: int, aux: dict,
               t0: float) -> SnapshotInfo:
        try:
            return self._write_inner(plan, step, aux, t0)
        except BaseException:
            # planning already advanced the mirror; a half-written store
            # would make the NEXT diff record stale parent refs.  Drop the
            # mirror so the next snapshot is a full base image.
            self._mirror.clear()
            self._prev_refs.clear()
            raise

    def _write_inner(self, plan: List[_TensorPlan], step: int, aux: dict,
                     t0: float) -> SnapshotInfo:
        before_put = self.store.stats["put_bytes"]
        before_dedup = self.store.stats["dedup_bytes"]
        cb = self.store.chunk_bytes
        tensors = {}
        total = changed = reused = reused_bytes = 0
        for p in plan:
            total += p.nbytes
            if p.base is not None:
                flat = p.base.reshape(-1).view(np.uint8)
                refs = self.store.put_buffer(memoryview(flat))
                changed += len(refs)
            else:
                prev_refs = self._prev_refs[p.key]
                new_flat = self._mirror[p.key].reshape(-1).view(np.uint8)
                refs = []
                for ci, pref in enumerate(prev_refs):
                    xor = p.deltas.get(ci)
                    if xor is None:
                        refs.append(pref)
                        reused += 1
                        reused_bytes += max(
                            0, min((ci + 1) * cb, p.nbytes) - ci * cb)
                    else:
                        s, e = ci * cb, min((ci + 1) * cb, p.nbytes)
                        refs.append(self.store.put_delta(
                            pref, xor, full_bytes=new_flat[s:e].tobytes()))
                        changed += 1
            tensors[p.key] = TensorEntry(p.shape, p.dtype, refs)
            self._prev_refs[p.key] = refs
        # chain reuse counts as dedup, as the v1 hash-everything path did
        self.store.stats["dedup_bytes"] += reused_bytes
        self.store.stats["dedup_chunks"] += reused
        self._counter += 1
        sid = f"snap-{self._counter:06d}-{sha256(str(step).encode())[:8]}"
        parent = self.order[-1] if self.order else None
        man = Manifest(sid, parent, step, time.time(), tensors, aux,
                       kind="base" if parent is None else "diff")
        self.manifests[sid] = man
        self.order.append(sid)
        if self.root is not None:
            (self.root / "manifests" / f"{sid}.json").write_text(man.to_json())
        self.gc() if self.auto_gc else self._trim_manifests()
        return SnapshotInfo(
            snapshot_id=sid, step=step, kind=man.kind,
            wall_s=time.time() - t0,
            new_bytes=self.store.stats["put_bytes"] - before_put,
            dedup_bytes=self.store.stats["dedup_bytes"] - before_dedup,
            total_bytes=total,
            changed_chunks=changed, reused_chunks=reused)

    # ------------------------------------------------------------------
    def restore(self, snapshot_id: Optional[str] = None, *,
                target_tree=None, shardings=None):
        """Rebuild state (optionally re-sharded onto a new mesh).

        Returns (state, aux).  ``target_tree`` supplies the pytree structure
        (e.g. abstract state); flattened key paths must match the manifest.
        Handles v2 (delta-ref) and v1 (hash-list) manifests alike.
        """
        self.wait()
        sid = snapshot_id or (self.order[-1] if self.order else None)
        if sid is None:
            raise ValueError("no snapshots available")
        man = self.get_manifest(sid)
        arrays = {}
        for key, ent in man.tensors.items():
            data = self.store.resolve_buffer(ent.refs)
            arr = np.frombuffer(data, dtype=np.dtype(ent.dtype))
            arrays[key] = arr.reshape(ent.shape)
        if target_tree is None:
            return arrays, man.aux
        leaves, treedef = jax.tree_util.tree_flatten(target_tree)
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(target_tree)[0]]
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, sh_leaves):
            if path not in arrays:
                raise KeyError(f"snapshot missing tensor {path}")
            a = arrays[path]
            out.append(jax.device_put(a, sh) if sh is not None else a)
        return jax.tree_util.tree_unflatten(treedef, out), man.aux

    def load_existing(self) -> int:
        """Adopt manifests already on disk under ``root`` (a previous
        process's chain) into this manager's order.

        Ordered by ``(step, created)``, NOT filename: snapshot ids restart
        per process, so a resumed run's newest snapshot can sort first by
        name.  v1 (``hashes``) and v2 (``refs``) manifests mix freely in
        one directory.  Returns the number of manifests adopted."""
        if self.root is None:
            raise ValueError("load_existing needs an on-disk root")
        mans = [Manifest.from_json(p.read_text())
                for p in sorted((self.root / "manifests").glob("*.json"))]
        adopted = 0
        for man in sorted(mans, key=lambda m: (m.step, m.created)):
            if man.snapshot_id in self.manifests:
                continue
            self.manifests[man.snapshot_id] = man
            self.order.append(man.snapshot_id)
            adopted += 1
        # new snapshots must not reuse an adopted id slot
        self._counter = max(self._counter, len(self.order))
        return adopted

    def get_manifest(self, sid: str) -> Manifest:
        """In-memory manifest, falling back to the on-disk copy."""
        man = self.manifests.get(sid)
        return man if man is not None else self._load_manifest(sid)

    def _load_manifest(self, sid: str) -> Manifest:
        if self.root is None:
            raise KeyError(sid)
        man = Manifest.from_json(
            (self.root / "manifests" / f"{sid}.json").read_text())
        self.manifests[sid] = man
        return man

    # ------------------------------------------------------------------
    def download_plan(self, client_refs: set[str],
                      snapshot_id: Optional[str] = None):
        """Block-level transfer accounting for a re-attaching volunteer.

        -> (missing refs, bytes to move, bytes saved) for the given (or
        latest) snapshot — the same ``ChunkStore.transfer_plan`` the
        server's ``fetch_capsule`` uses."""
        sid = snapshot_id or (self.order[-1] if self.order else None)
        if sid is None:
            raise ValueError("no snapshots available")
        return self.store.transfer_plan(self.get_manifest(sid).all_refs(),
                                        client_refs)

    # ------------------------------------------------------------------
    def _trim_manifests(self) -> None:
        while len(self.order) > self.keep_last:
            sid = self.order.pop(0)
            man = self.manifests.pop(sid, None)
            if man is not None and self.root is not None:
                p = self.root / "manifests" / f"{sid}.json"
                if p.exists():
                    p.unlink()

    def gc(self) -> int:
        """Keep the last ``keep_last`` snapshots; mark the closure of their
        refs (delta parents stay live) and sweep the store."""
        self._trim_manifests()
        live: set[str] = set()
        for man in self.manifests.values():
            live.update(man.all_refs())
        return self.store.gc(live)

    def latest(self) -> Optional[str]:
        return self.order[-1] if self.order else None
