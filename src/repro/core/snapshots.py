"""System-level checkpointing with device-resident differencing snapshots.

The SnapshotManager checkpoints the ENTIRE program state transparently —
params, optimizer moments, data cursor, RNG, step — so "project developers
omit application-level checkpointing from their code" (paper §III-E).
Mechanics mirror VirtualBox snapshots, but the diff is computed *before*
anything crosses the device→host boundary:

* ``snapshot()`` — the first snapshot is a full base image.  Every later
  one is a *differencing image*: the fused Pallas probe+gather kernel
  (kernels/delta_encode) XORs the new state against a **device-resident
  mirror** of the previous snapshot (double-buffered: after each diff the
  new tiles become the mirror by reference swap, so no H→D re-upload),
  size-bucketed so the whole pytree diffs in a few concatenated launches.
  Only the changed tiles cross to host.  Unchanged store chunks re-use the
  parent manifest's refs with **no hashing at all**, and changed chunks
  are written as delta objects (``parent_ref + RLE XOR``) — snapshot cost
  is O(changed blocks), not O(state bytes).
* **Async writer** (``async_mode=True``) — the calling thread runs ONLY
  the device probe + changed-tile transfer (``probe_leaves``); chunk
  compaction, hashing, RLE, ``put_delta`` and deferred ``max_chain``
  rebase run on a background ``SnapshotWriter`` behind a bounded queue,
  so the trainer's stall is the probe and nothing else
  (``SnapshotInfo.stall_ms`` vs ``writer_ms``).  Plans are self-contained
  (they carry the changed tiles + bitmap, or the full base image); the
  writer keeps its OWN host image per tensor and advances it serially, so
  writer and planner share no mutable state.  A half-written snapshot
  stays invisible: the manifest registers only after every object landed,
  and a write failure poisons the queue — the next snapshot re-bases from
  a fresh base image, exactly the ``_mirror.clear()`` invariant of the
  inline path.
* **Manifest v2** — each ``TensorEntry`` records per-block refs that are
  either raw hashes or ``"d:"`` delta refs.  v1 manifests (``hashes``)
  remain readable, so old snapshot directories restore unchanged.
* ``restore(sid)`` — resolve each ref through its base chain
  (``ChunkStore.resolve``) and rebuild the pytree; chains are bounded by
  the store's ``max_chain`` (deep chains rebase automatically).
* ``delete/gc`` — mark the *closure* of live refs from retained
  snapshots (a delta keeps its parents alive), sweep the rest.  The mark
  and the sweep hold the store's ``gc_lock`` so a concurrent background
  write can never have a just-written, not-yet-committed object swept.

Restore across meshes: manifests record logical tensors (path, shape,
dtype); ``restore`` re-shards onto whatever mesh the caller's shardings
dictate — this is what lets a capsule resume on a *different* volunteer
pod (elastic rescale).
"""
from __future__ import annotations

import contextlib
import json
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.chunkstore import ChunkStore, sha256
from repro.core.writer import SnapshotWriter
from repro.kernels.delta_encode.ops import (DeviceMirror, chunk_records,
                                            probe_leaves)

MANIFEST_VERSION = 2


def _flatten(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


@dataclass
class TensorEntry:
    shape: tuple
    dtype: str
    refs: List[str]           # per-block: raw sha256 hex | "d:" delta ref

    # v1 manifests named this field "hashes"; keep the alias for callers
    @property
    def hashes(self) -> List[str]:
        return self.refs

    def to_json(self):
        return {"shape": list(self.shape), "dtype": self.dtype,
                "refs": self.refs}

    @classmethod
    def from_json(cls, d):
        return cls(tuple(d["shape"]), d["dtype"],
                   list(d.get("refs", d.get("hashes", []))))


@dataclass
class Manifest:
    snapshot_id: str
    parent: Optional[str]
    step: int
    created: float
    tensors: Dict[str, TensorEntry]
    aux: dict = field(default_factory=dict)      # cursor, rng seed, metrics
    kind: str = "diff"                            # base | diff
    version: int = MANIFEST_VERSION

    def all_refs(self) -> List[str]:
        return [r for ent in self.tensors.values() for r in ent.refs]

    def to_json(self) -> str:
        return json.dumps({
            "version": self.version,
            "snapshot_id": self.snapshot_id, "parent": self.parent,
            "step": self.step, "created": self.created, "kind": self.kind,
            "aux": self.aux,
            "tensors": {k: t.to_json() for k, t in self.tensors.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        d = json.loads(s)
        return cls(d["snapshot_id"], d["parent"], d["step"], d["created"],
                   {k: TensorEntry.from_json(t)
                    for k, t in d["tensors"].items()},
                   d.get("aux", {}), d.get("kind", "diff"),
                   d.get("version", 1))


@dataclass
class SnapshotInfo:
    snapshot_id: str
    step: int
    kind: str
    wall_s: float
    new_bytes: int        # differencing-image cost (changed blocks)
    dedup_bytes: int      # blocks reused from the chain
    total_bytes: int      # logical state size
    changed_chunks: int = 0
    reused_chunks: int = 0
    stall_ms: float = 0.0     # trainer-visible time (plan [+ write inline])
    plan_ms: float = 0.0      # device probe + changed-tile transfer
    writer_ms: float = 0.0    # background chunk/hash/RLE/store/rebase time


@dataclass
class _TensorPlan:
    """Per-tensor work captured synchronously at snapshot() time.

    Self-contained: either the full host image (``base``, re-base path) or
    the probe's compacted changed tiles + bitmap (delta path).  The writer
    folds tiles into its OWN host image (``SnapshotManager._mirror``, which
    only the writer advances), so planner and writer share no mutable
    state and the planner never touches host chunk layout at all."""
    key: str
    shape: tuple
    dtype: str
    nbytes: int
    base: Optional[np.ndarray] = None        # full host image (base path)
    tiles: Optional[np.ndarray] = None       # compacted changed 32 KiB tiles
    bitmap: Optional[np.ndarray] = None      # per-tile changed flags


class SnapshotManager:
    def __init__(self, store: ChunkStore,
                 root: Optional[Path] = None,
                 keep_last: int = 3,
                 async_mode: bool = False,
                 writer_depth: int = 2,
                 auto_gc: bool = True,
                 delta: bool = True,
                 delta_mode: str = "auto",
                 telemetry=None):
        self.store = store
        self.telemetry = telemetry
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        # when the store is SHARED across managers (DiskSet), per-manager
        # sweeps would delete sibling disks' chunks — the owner must run a
        # global mark (DiskSet.gc_all) instead.
        self.auto_gc = auto_gc
        # delta=False falls back to the v1 full-hash path (every snapshot
        # re-hashes every chunk); delta_mode picks the diff backend:
        # "auto" (TPU kernel on TPU, numpy oracle elsewhere), "tpu",
        # "interpret", "ref".
        self.delta = delta
        self.delta_mode = delta_mode
        self.manifests: Dict[str, Manifest] = {}
        self.order: List[str] = []                 # snapshot chain
        self._writer = SnapshotWriter(self._write_bg, depth=writer_depth,
                                      telemetry=telemetry) \
            if async_mode else None
        self._futures: deque[Future] = deque()
        self.last_info: Optional[SnapshotInfo] = None
        self._counter = 0
        # host byte image per tensor, advanced ONLY by the write path
        # (writer thread in async mode) — the probing thread never reads it
        self._mirror: Dict[str, np.ndarray] = {}
        self._device_mirror = DeviceMirror()       # probe-side tiles (no H→D)
        self._prev_refs: Dict[str, List[str]] = {}

    @property
    def is_async(self) -> bool:
        return self._writer is not None

    @property
    def writer_stats(self) -> dict:
        return dict(self._writer.stats) if self._writer is not None else {}

    # ------------------------------------------------------------------
    def snapshot(self, state, *, step: int, aux: Optional[dict] = None,
                 block: bool = True) -> SnapshotInfo | Future:
        """Take a snapshot.  ``state`` is any pytree of arrays.

        Planning (device probe + changed-tile transfer) is synchronous;
        with ``async_mode`` chunk compaction and the store/manifest writes
        run on the background writer and ``block=False`` returns the
        write's Future immediately — the caller's stall is the probe plus
        queue backpressure, nothing else."""
        self._reap()             # surface any finished/failed async write
        t0 = time.time()
        tp = time.perf_counter()
        try:
            plan = self._plan_state(state)
        except BaseException:
            # a partial plan has already advanced some tensors' mirrors
            # while _prev_refs still points at the old chunks; drop both so
            # the next snapshot re-bases instead of recording stale refs
            self._poison()
            raise
        plan_ms = (time.perf_counter() - tp) * 1e3
        if self._writer is not None:
            try:
                fut = self._writer.submit(plan, step, aux or {}, t0, plan_ms)
            except BaseException:
                self._poison()
                raise
            self._futures.append(fut)
            return self.wait() if block else fut
        return self._write_sync(plan, step, aux or {}, t0, plan_ms)

    def wait(self) -> Optional[SnapshotInfo]:
        """Drain pending background writes; returns the last SnapshotInfo.
        Raises (once) if any pending write failed, after re-basing."""
        out = self.last_info if self._futures else None
        try:
            while self._futures:
                out = self._futures.popleft().result()
                self.last_info = out
        except BaseException:
            self._poison()
            raise
        return out

    def close(self) -> None:
        """Drain the writer and stop its thread."""
        try:
            self.wait()
        finally:
            if self._writer is not None:
                self._writer.close()

    def _reap(self) -> None:
        """Non-blocking: collect already-finished async writes (keeps the
        future list bounded and surfaces failures at the next snapshot)."""
        while self._futures and self._futures[0].done():
            fut = self._futures.popleft()
            try:
                self.last_info = fut.result()
            except BaseException:
                self._poison()
                raise

    def _poison(self) -> None:
        """Re-base after a failure: drain valid queued writes, then drop
        every mirror so the next snapshot records a full base image rather
        than delta refs against parents that never landed."""
        if self._writer is not None:
            while self._futures:
                fut = self._futures.popleft()
                with contextlib.suppress(BaseException):
                    self.last_info = fut.result()
            self._writer.reset()
        self._mirror.clear()
        self._device_mirror.clear()
        self._prev_refs.clear()

    # ------------------------------------------------------------------
    def _plan_state(self, state) -> List[_TensorPlan]:
        """Probe the whole pytree in size-bucketed fused launches against
        the device-resident mirror slots — this is ALL the work the
        calling thread does per tensor.  Leaves the probe reports as
        un-probed (first snapshot, shape/dtype change, bucket membership
        change) fall back to full base images; the probe seeded their
        slots, so the next round diffs them."""
        flat = [(k, leaf if hasattr(leaf, "dtype") else np.asarray(leaf))
                for k, leaf in _flatten(state)]
        probes = {}
        if self.delta and flat:
            probes = probe_leaves(dict(flat), mode=self.delta_mode,
                                  mirror=self._device_mirror)
        plans = []
        for key, leaf in flat:
            pr = probes.get(key)
            if pr is None:
                plans.append(self._plan_base(key, leaf))
            else:
                tiles, bitmap, nbytes = pr
                plans.append(_TensorPlan(key, tuple(leaf.shape),
                                         str(leaf.dtype), nbytes,
                                         tiles=tiles, bitmap=bitmap))
        return plans

    def _plan_base(self, key: str, leaf) -> _TensorPlan:
        shape, dtype = tuple(leaf.shape), str(leaf.dtype)
        host = np.ascontiguousarray(np.asarray(leaf))
        if host.shape != shape:
            host = host.reshape(shape)   # ascontiguousarray 0-d -> 1-d
        if host is leaf or host.base is not None:
            host = host.copy()       # plan must not alias caller data
        return _TensorPlan(key, shape, dtype, host.nbytes, base=host)

    # ------------------------------------------------------------------
    def _write_sync(self, plan, step, aux, t0, plan_ms) -> SnapshotInfo:
        try:
            info = self._write_inner(plan, step, aux, t0)
        except BaseException:
            # the probe already swapped the device mirror forward; a
            # half-written store would make the NEXT diff record stale
            # parent refs.  Drop the mirrors so the next snapshot is a
            # full base image.
            self._poison()
            raise
        info.plan_ms = plan_ms
        info.stall_ms = info.wall_s * 1e3    # inline: the trainer paid it all
        self.last_info = info
        return info

    def _write_bg(self, plan, step, aux, t0, plan_ms) -> SnapshotInfo:
        tw = time.perf_counter()
        info = self._write_inner(plan, step, aux, t0)
        info.plan_ms = plan_ms
        info.stall_ms = plan_ms              # trainer paid only the plan
        info.writer_ms = (time.perf_counter() - tw) * 1e3
        return info

    def _write_inner(self, plan: List[_TensorPlan], step: int, aux: dict,
                     t0: float) -> SnapshotInfo:
        before_put = self.store.stats["put_bytes"]
        before_dedup = self.store.stats["dedup_bytes"]
        cb = self.store.chunk_bytes
        tensors = {}
        total = changed = reused = reused_bytes = 0
        # hold the store's gc lock across write + manifest commit so a
        # concurrent mark/sweep can never see (and sweep) this snapshot's
        # objects while its manifest is still unregistered
        with self._gc_guard():
            for p in plan:
                total += p.nbytes
                if p.base is not None:
                    flat = np.asarray(p.base).reshape(-1).view(np.uint8)
                    refs = self.store.put_buffer(memoryview(flat))
                    changed += len(refs)
                    self._mirror[p.key] = flat
                else:
                    # fold the probe's tiles into the writer's host image
                    # and derive per-chunk XOR records — off the hot path
                    prev_refs = self._prev_refs[p.key]
                    records: Dict[int, bytes] = {}
                    new_flat = None
                    if p.bitmap is not None and p.bitmap.any():
                        records, new_flat = chunk_records(
                            self._mirror[p.key], p.tiles, p.bitmap,
                            p.nbytes, cb)
                    refs = []
                    for ci, pref in enumerate(prev_refs):
                        xor = records.get(ci)
                        if xor is None:
                            refs.append(pref)
                            reused += 1
                            reused_bytes += max(
                                0, min((ci + 1) * cb, p.nbytes) - ci * cb)
                        else:
                            cs = ci * cb
                            ce = min(cs + cb, p.nbytes)
                            refs.append(self.store.put_delta(
                                pref, xor,
                                full_bytes=new_flat[cs:ce].tobytes()))
                            changed += 1
                    if new_flat is not None:
                        self._mirror[p.key] = new_flat
                tensors[p.key] = TensorEntry(p.shape, p.dtype, refs)
                self._prev_refs[p.key] = refs
            # chain reuse counts as dedup, as the v1 hash-everything path did
            self.store.metrics.dedup_bytes.inc(reused_bytes)
            self.store.metrics.dedup_chunks.inc(reused)
            self._counter += 1
            sid = f"snap-{self._counter:06d}-{sha256(str(step).encode())[:8]}"
            parent = self.order[-1] if self.order else None
            man = Manifest(sid, parent, step, time.time(), tensors, aux,
                           kind="base" if parent is None else "diff")
            self.manifests[sid] = man
            self.order.append(sid)
            if self.root is not None:
                (self.root / "manifests" / f"{sid}.json") \
                    .write_text(man.to_json())
        self.gc() if self.auto_gc else self._trim_manifests()
        return SnapshotInfo(
            snapshot_id=sid, step=step, kind=man.kind,
            wall_s=time.time() - t0,
            new_bytes=self.store.stats["put_bytes"] - before_put,
            dedup_bytes=self.store.stats["dedup_bytes"] - before_dedup,
            total_bytes=total,
            changed_chunks=changed, reused_chunks=reused)

    def _gc_guard(self):
        lock = getattr(self.store, "gc_lock", None)
        return lock if lock is not None else contextlib.nullcontext()

    # ------------------------------------------------------------------
    def restore(self, snapshot_id: Optional[str] = None, *,
                target_tree=None, shardings=None):
        """Rebuild state (optionally re-sharded onto a new mesh).

        Returns (state, aux).  ``target_tree`` supplies the pytree structure
        (e.g. abstract state); flattened key paths must match the manifest.
        Handles v2 (delta-ref) and v1 (hash-list) manifests alike.
        """
        self.wait()
        sid = snapshot_id or (self.order[-1] if self.order else None)
        if sid is None:
            raise ValueError("no snapshots available")
        man = self.get_manifest(sid)
        arrays = {}
        for key, ent in man.tensors.items():
            data = self.store.resolve_buffer(ent.refs)
            arr = np.frombuffer(data, dtype=np.dtype(ent.dtype))
            arrays[key] = arr.reshape(ent.shape)
        if target_tree is None:
            return arrays, man.aux
        leaves, treedef = jax.tree_util.tree_flatten(target_tree)
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(target_tree)[0]]
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, sh_leaves):
            if path not in arrays:
                raise KeyError(f"snapshot missing tensor {path}")
            a = arrays[path]
            out.append(jax.device_put(a, sh) if sh is not None else a)
        return jax.tree_util.tree_unflatten(treedef, out), man.aux

    def load_existing(self) -> int:
        """Adopt manifests already on disk under ``root`` (a previous
        process's chain) into this manager's order.

        Ordered by ``(step, created)``, NOT filename: snapshot ids restart
        per process, so a resumed run's newest snapshot can sort first by
        name.  v1 (``hashes``) and v2 (``refs``) manifests mix freely in
        one directory.  Returns the number of manifests adopted."""
        if self.root is None:
            raise ValueError("load_existing needs an on-disk root")
        mans = [Manifest.from_json(p.read_text())
                for p in sorted((self.root / "manifests").glob("*.json"))]
        adopted = 0
        for man in sorted(mans, key=lambda m: (m.step, m.created)):
            if man.snapshot_id in self.manifests:
                continue
            self.manifests[man.snapshot_id] = man
            self.order.append(man.snapshot_id)
            adopted += 1
        # new snapshots must not reuse an adopted id slot
        self._counter = max(self._counter, len(self.order))
        return adopted

    def get_manifest(self, sid: str) -> Manifest:
        """In-memory manifest, falling back to the on-disk copy."""
        man = self.manifests.get(sid)
        return man if man is not None else self._load_manifest(sid)

    def _load_manifest(self, sid: str) -> Manifest:
        if self.root is None:
            raise KeyError(sid)
        man = Manifest.from_json(
            (self.root / "manifests" / f"{sid}.json").read_text())
        self.manifests[sid] = man
        return man

    # ------------------------------------------------------------------
    def download_plan(self, client_refs: set[str],
                      snapshot_id: Optional[str] = None):
        """Block-level transfer accounting for a re-attaching volunteer.

        -> (missing refs, bytes to move, bytes saved) for the given (or
        latest) snapshot — the same ``ChunkStore.plan_send`` (Wire) the
        server's ``fetch_capsule`` uses."""
        self.wait()
        sid = snapshot_id or (self.order[-1] if self.order else None)
        if sid is None:
            raise ValueError("no snapshots available")
        return self.store.plan_send(self.get_manifest(sid).all_refs(),
                                    client_refs)

    # ------------------------------------------------------------------
    def _trim_manifests(self) -> None:
        while len(self.order) > self.keep_last:
            sid = self.order.pop(0)
            man = self.manifests.pop(sid, None)
            if man is not None and self.root is not None:
                p = self.root / "manifests" / f"{sid}.json"
                if p.exists():
                    p.unlink()

    def gc(self) -> int:
        """Keep the last ``keep_last`` snapshots; mark the closure of their
        refs (delta parents stay live) and sweep the store.  Mark + sweep
        run under the store's gc lock so an in-flight background write
        commits its manifest before the live set is collected."""
        with self._gc_guard():
            self._trim_manifests()
            live: set[str] = set()
            for man in self.manifests.values():
                live.update(man.all_refs())
            return self.store.gc(live)

    def latest(self) -> Optional[str]:
        return self.order[-1] if self.order else None
