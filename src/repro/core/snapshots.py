"""System-level checkpointing with differencing snapshots (paper §III-E).

The SnapshotManager checkpoints the ENTIRE program state transparently —
params, optimizer moments, data cursor, RNG, step — so "project developers
omit application-level checkpointing from their code".  Mechanics mirror
VirtualBox snapshots:

* ``snapshot()``       -> manifest of per-tensor chunk hashes.  The first is a
  full base image; each later one is a *differencing image*: unchanged chunks
  dedup to the parent's objects, so stored bytes == changed blocks only.
* ``restore(sid)``     -> resolve the manifest chain and rebuild the pytree.
* ``delete/gc``        -> "previous stale snapshot files … are deleted by
  V-BOINC": mark live chunks from retained snapshots, sweep the rest.
* async mode           -> device→host transfer happens synchronously (cheap),
  hashing + store writes run on a background thread so checkpointing overlaps
  training compute (the distributed-optimization trick at scale).

Restore across meshes: manifests record logical tensors (path, shape, dtype);
``restore`` re-shards onto whatever mesh the caller's shardings dictate —
this is what lets a capsule resume on a *different* volunteer pod (elastic
rescale).
"""
from __future__ import annotations

import json
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.chunkstore import ChunkStore, sha256


def _flatten(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


@dataclass
class TensorEntry:
    shape: tuple
    dtype: str
    hashes: List[str]

    def to_json(self):
        return {"shape": list(self.shape), "dtype": self.dtype,
                "hashes": self.hashes}

    @classmethod
    def from_json(cls, d):
        return cls(tuple(d["shape"]), d["dtype"], list(d["hashes"]))


@dataclass
class Manifest:
    snapshot_id: str
    parent: Optional[str]
    step: int
    created: float
    tensors: Dict[str, TensorEntry]
    aux: dict = field(default_factory=dict)      # cursor, rng seed, metrics
    kind: str = "diff"                            # base | diff

    def to_json(self) -> str:
        return json.dumps({
            "snapshot_id": self.snapshot_id, "parent": self.parent,
            "step": self.step, "created": self.created, "kind": self.kind,
            "aux": self.aux,
            "tensors": {k: t.to_json() for k, t in self.tensors.items()},
        })

    @classmethod
    def from_json(cls, s: str) -> "Manifest":
        d = json.loads(s)
        return cls(d["snapshot_id"], d["parent"], d["step"], d["created"],
                   {k: TensorEntry.from_json(t)
                    for k, t in d["tensors"].items()},
                   d.get("aux", {}), d.get("kind", "diff"))


@dataclass
class SnapshotInfo:
    snapshot_id: str
    step: int
    kind: str
    wall_s: float
    new_bytes: int        # differencing-image cost (changed blocks)
    dedup_bytes: int      # blocks reused from the chain
    total_bytes: int      # logical state size


class SnapshotManager:
    def __init__(self, store: ChunkStore,
                 root: Optional[Path] = None,
                 keep_last: int = 3,
                 async_mode: bool = False,
                 auto_gc: bool = True):
        self.store = store
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        # when the store is SHARED across managers (DiskSet), per-manager
        # sweeps would delete sibling disks' chunks — the owner must run a
        # global mark (DiskSet.gc_all) instead.
        self.auto_gc = auto_gc
        self.manifests: Dict[str, Manifest] = {}
        self.order: List[str] = []                 # snapshot chain
        self._pool = ThreadPoolExecutor(max_workers=1) if async_mode else None
        self._pending: Optional[Future] = None
        self._counter = 0

    # ------------------------------------------------------------------
    def snapshot(self, state, *, step: int, aux: Optional[dict] = None,
                 block: bool = True) -> SnapshotInfo | Future:
        """Take a snapshot.  ``state`` is any pytree of arrays."""
        t0 = time.time()
        host = [(k, np.asarray(v)) for k, v in _flatten(state)]
        if self._pool is not None and not block:
            if self._pending is not None:      # back-pressure: one in flight
                self._pending.result()
            self._pending = self._pool.submit(
                self._write, host, step, aux or {}, t0)
            return self._pending
        return self._write(host, step, aux or {}, t0)

    def wait(self) -> Optional[SnapshotInfo]:
        if self._pending is not None:
            info = self._pending.result()
            self._pending = None
            return info
        return None

    def _write(self, host, step: int, aux: dict, t0: float) -> SnapshotInfo:
        before_put = self.store.stats["put_bytes"]
        before_dedup = self.store.stats["dedup_bytes"]
        tensors = {}
        total = 0
        for key, arr in host:
            buf = memoryview(np.ascontiguousarray(arr)).cast("B")
            total += buf.nbytes
            tensors[key] = TensorEntry(arr.shape, str(arr.dtype),
                                       self.store.put_buffer(buf))
        self._counter += 1
        sid = f"snap-{self._counter:06d}-{sha256(str(step).encode())[:8]}"
        parent = self.order[-1] if self.order else None
        man = Manifest(sid, parent, step, time.time(), tensors, aux,
                       kind="base" if parent is None else "diff")
        self.manifests[sid] = man
        self.order.append(sid)
        if self.root is not None:
            (self.root / "manifests" / f"{sid}.json").write_text(man.to_json())
        self.gc() if self.auto_gc else self._trim_manifests()
        return SnapshotInfo(
            snapshot_id=sid, step=step, kind=man.kind,
            wall_s=time.time() - t0,
            new_bytes=self.store.stats["put_bytes"] - before_put,
            dedup_bytes=self.store.stats["dedup_bytes"] - before_dedup,
            total_bytes=total)

    # ------------------------------------------------------------------
    def restore(self, snapshot_id: Optional[str] = None, *,
                target_tree=None, shardings=None):
        """Rebuild state (optionally re-sharded onto a new mesh).

        Returns (state, aux).  ``target_tree`` supplies the pytree structure
        (e.g. abstract state); flattened key paths must match the manifest.
        """
        self.wait()
        sid = snapshot_id or (self.order[-1] if self.order else None)
        if sid is None:
            raise ValueError("no snapshots available")
        man = self.manifests.get(sid) or self._load_manifest(sid)
        arrays = {}
        for key, ent in man.tensors.items():
            data = self.store.get_buffer(ent.hashes)
            arr = np.frombuffer(data, dtype=np.dtype(ent.dtype))
            arrays[key] = arr.reshape(ent.shape)
        if target_tree is None:
            return arrays, man.aux
        leaves, treedef = jax.tree_util.tree_flatten(target_tree)
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(target_tree)[0]]
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, sh_leaves):
            if path not in arrays:
                raise KeyError(f"snapshot missing tensor {path}")
            a = arrays[path]
            out.append(jax.device_put(a, sh) if sh is not None else a)
        return jax.tree_util.tree_unflatten(treedef, out), man.aux

    def _load_manifest(self, sid: str) -> Manifest:
        if self.root is None:
            raise KeyError(sid)
        man = Manifest.from_json(
            (self.root / "manifests" / f"{sid}.json").read_text())
        self.manifests[sid] = man
        return man

    # ------------------------------------------------------------------
    def _trim_manifests(self) -> None:
        while len(self.order) > self.keep_last:
            sid = self.order.pop(0)
            man = self.manifests.pop(sid, None)
            if man is not None and self.root is not None:
                p = self.root / "manifests" / f"{sid}.json"
                if p.exists():
                    p.unlink()

    def gc(self) -> int:
        """Keep the last ``keep_last`` snapshots; mark-and-sweep the store."""
        self._trim_manifests()
        live: set[str] = set()
        for man in self.manifests.values():
            for ent in man.tensors.values():
                live.update(ent.hashes)
        return self.store.gc(live)

    def latest(self) -> Optional[str]:
        return self.order[-1] if self.order else None
