"""Delta-aware volunteer uplink: quantized round updates as store objects.

PR 1 made the *downlink* pay only changed blocks (``plan_send``); this
module closes the loop for the uplink.  A volunteer's per-round
gradient/optimizer update is first quantized to int8 with per-block scales
(``optim/grad_compress`` — the dense wire format), then the quantized byte
image is diffed against the volunteer's previous round with the same
probe-then-gather kernel the snapshot path uses
(``kernels/delta_encode.changed_blocks(emit="records")``), and only the
changed chunks become chunk-store objects.  The XOR payload is computed
over the *quantized* representation, so a sparse update — most gradient
blocks unchanged, optimizer moments frozen — uploads a handful of RLE'd
delta records instead of the full int8 payload.

Protocol (in-process analogue of the two-round-trip wire exchange):

1. client ``encode()`` writes the round's objects into its *local* store
   and returns an ``UplinkUpdate`` (refs + leaf metadata + a handle to
   that store);
2. server ``plan_recv`` answers which refs it lacks (per-client dedup:
   two volunteers pushing the same zero-chunk move it once);
3. client ``send`` ships exactly those; server ``recv`` re-hashes every
   record and refuses dangling chains.

Both directions speak the unified ``Wire`` protocol
(``plan_send``/``plan_recv``/``send``/``recv`` in ``core/chunkstore``).

``decode_update`` is the server-side fold: resolve each ref chain back to
the quantized image and rebuild the ``Compressed`` leaves — the canonical
round state a re-attaching volunteer (or the validator) reads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.chunkstore import ChunkStore
from repro.kernels.delta_encode.ops import changed_blocks
from repro.optim.grad_compress import BLOCK, Compressed

DEFAULT_UPLINK_CHUNK = 1 << 15           # 32 KiB uplink chunks


@dataclass
class LeafMeta:
    """Shape/dtype sidecar so the server can rebuild ``Compressed`` leaves."""
    shape: tuple
    dtype: str
    blocks: int                          # int8 quantization blocks

    @property
    def q_bytes(self) -> int:
        return self.blocks * BLOCK

    @property
    def image_bytes(self) -> int:        # q int8 payload + f32 scales
        return self.blocks * (BLOCK + 4)


@dataclass
class UplinkUpdate:
    """One volunteer round update: per-leaf refs into the client store."""
    refs: Dict[str, List[str]]
    meta: Dict[str, LeafMeta]
    dense_bytes: int                     # int8+scale wire bytes, no dedup
    store: ChunkStore                    # client-local store holding them

    def all_refs(self) -> List[str]:
        return [r for refs in self.refs.values() for r in refs]


def leaf_image(comp: Compressed) -> np.ndarray:
    """Flat uint8 image of one quantized leaf: q int8 bytes + f32 scales."""
    q = np.ascontiguousarray(np.asarray(comp.q, np.int8))
    scale = np.ascontiguousarray(np.asarray(comp.scale, np.float32))
    return np.concatenate([q.reshape(-1).view(np.uint8),
                           scale.reshape(-1).view(np.uint8)])


def flatten_compressed(comp_tree) -> Dict[str, tuple[Compressed, str]]:
    """keypath -> Compressed leaf, keyed like snapshot manifests."""
    import jax
    leaves = jax.tree_util.tree_flatten_with_path(
        comp_tree, is_leaf=lambda x: isinstance(x, Compressed))[0]
    return {jax.tree_util.keystr(p): l for p, l in leaves}


class UplinkEncoder:
    """Client-side differencing encoder; one per volunteer.

    Keeps the previous round's quantized byte image (host mirror) and the
    refs it stored, exactly like ``SnapshotManager`` does for state — the
    uplink is the snapshot pipeline pointed the other way."""

    def __init__(self, *, chunk_bytes: int = DEFAULT_UPLINK_CHUNK,
                 max_chain: int = 8, mode: str = "auto",
                 store: ChunkStore | None = None):
        self.store = store or ChunkStore(chunk_bytes=chunk_bytes,
                                         max_chain=max_chain)
        self.mode = mode
        self._mirror: Dict[str, np.ndarray] = {}
        self._prev_refs: Dict[str, List[str]] = {}

    def encode(self, comp_tree) -> UplinkUpdate:
        """Encode one round's quantized update into store objects."""
        cb = self.store.chunk_bytes
        refs: Dict[str, List[str]] = {}
        meta: Dict[str, LeafMeta] = {}
        dense = 0
        for key, comp in flatten_compressed(comp_tree).items():
            img = leaf_image(comp)
            dense += img.size
            blocks = int(np.asarray(comp.scale).reshape(-1).size)
            meta[key] = LeafMeta(tuple(np.asarray(comp.q).shape),
                                 str(np.asarray(comp.q).dtype), blocks)
            prev = self._mirror.get(key)
            if prev is None or prev.size != img.size \
                    or key not in self._prev_refs:
                self._mirror[key] = img.copy()
                refs[key] = self.store.put_buffer(memoryview(img))
                self._prev_refs[key] = refs[key]
                continue
            # the image is blocks*(BLOCK+4) bytes — always 4-aligned — so
            # view it as int32: uint8 is not a kernel dtype and would
            # silently fall back to the host ref differ on TPU
            records, new_flat, nbytes = changed_blocks(
                prev.view(np.int32), img.view(np.int32), mode=self.mode,
                emit="records", chunk_bytes=cb)
            out: List[str] = []
            for ci, pref in enumerate(self._prev_refs[key]):
                xor = records.get(ci)
                if xor is None:
                    out.append(pref)
                else:
                    s, e = ci * cb, min((ci + 1) * cb, nbytes)
                    out.append(self.store.put_delta(
                        pref, xor, full_bytes=new_flat[s:e].tobytes()))
            self._mirror[key] = new_flat
            refs[key] = out
            self._prev_refs[key] = out
        return UplinkUpdate(refs, meta, dense, self.store)

    def gc(self) -> int:
        """Drop everything but the latest round's closure from the local
        store (a volunteer only ever diffs against its last round)."""
        live = {r for refs in self._prev_refs.values() for r in refs}
        return self.store.gc(live)


def push_update(update: UplinkUpdate, server_store: ChunkStore, *,
                client_id: str) -> tuple[int, int]:
    """Move one update into ``server_store``; only missing objects travel.

    -> (bytes moved up, bytes saved by dedup).  Raises ``IOError`` when a
    record fails validation (nothing is written).  Moved bytes come from
    ``recv``'s server-verified count, never the client's offered sizes,
    so the accounting the scheduler credits cannot be inflated."""
    closure = update.store.live_closure(update.all_refs())
    offered = {r: update.store.object_size(r) for r in closure}
    needed, _, dedup = server_store.plan_recv(offered,
                                              client_id=client_id)
    try:
        moved = server_store.recv(update.store.send(needed),
                                  client_id=client_id)
    except Exception:
        # nothing landed: claw the planned dedup back out of the client's
        # credit accounting and mark the rejection
        log = server_store.uplinks[client_id]
        log["bytes_dedup"] -= dedup
        log["rejected"] += 1
        server_store.metrics.ingest_dedup_bytes.inc(-dedup)
        raise
    return moved, dedup


def decode_update(store: ChunkStore, update: UplinkUpdate
                  ) -> Dict[str, Compressed]:
    """Resolve an update's ref chains back into ``Compressed`` leaves.

    Raises ``IOError``/``KeyError`` when a chain is broken or the resolved
    image does not match the leaf metadata — the server's chain
    validation."""
    out: Dict[str, Compressed] = {}
    for key, refs in update.refs.items():
        m = update.meta[key]
        img = store.resolve_buffer(refs)
        if len(img) != m.image_bytes:
            raise IOError(f"uplink leaf {key}: resolved {len(img)} bytes, "
                          f"expected {m.image_bytes}")
        q = np.frombuffer(img[:m.q_bytes], np.int8).reshape(m.blocks, BLOCK)
        scale = np.frombuffer(img[m.q_bytes:], np.float32)
        out[key] = Compressed(q, scale)
    return out
