"""Deterministic churn fault injection for the replication subsystem.

``ChurnSim`` drives a ``ReplicaSet`` through a scripted sequence of
steps — snapshot/uplink work on the hot path, replication pumps, message
delivery, and faults (kill/wipe/revive/promote, message drops, reordered
delivery) — with every random choice drawn from one seeded generator, so
a failing schedule replays bit-for-bit from its seed.

The same driver also churns the *scheduler plane*: constructed with
``shards=`` (a ``ShardedScheduler``) it churns scheduler membership —
scripted (``kill_shard``, ``add_shard``, ``split_hot_shard``,
``rejoin_shard``) or seeded (``random_shard_kill``) — so the elastic
handoff path (key-range reassignment + open-unit migration) is
exercised by the exact deterministic machinery that already drives
replica failover.  With ``edges=`` (an ``EdgeTier``) it churns the
edge-cache tier through the same shared ``Membership`` verbs:
``kill_cache``/``revive_cache`` (optionally *stale* — the cache comes
back empty and must demand-fill before serving) and seeded
``random_cache_kill``.  A sim may drive any combination of the three
planes.

Two instruments make the fault-injection suite's assertions possible:

* **message interception** — the sim installs itself as the set's
  ``transport``: pumped messages are captured in flight instead of being
  applied, then delivered (optionally in scrambled order) at an explicit
  ``deliver`` step.  ``drop(n)`` discards the next n sends, exercising the
  retry path; down members black-hole their messages.
* **step accounting** — every member's ``recv`` (Wire sink verb) is
  wrapped to log
  ``(step, phase, member, primary_at_the_time, records)``.  Scripted steps
  run in a named phase ("hot" for snapshot/training work, "net" for
  pump/deliver, "fault" for churn events), so a test can assert that *no
  peer ingest ever ran during a hot step* — replication adds zero blocking
  I/O to the snapshot hot path.
"""
from __future__ import annotations

from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import telemetry as tlm
from repro.core.replica import ReplicaSet


class ChurnSim:
    """Scripted, seedable kill/revive/drop/reorder driver for a ReplicaSet."""

    def __init__(self, replicas: Optional[ReplicaSet] = None, seed: int = 0,
                 *, shards=None, edges=None,
                 telemetry: Optional[tlm.Telemetry] = None,
                 dump_on_fault: Optional[Path] = None):
        if replicas is None and shards is None and edges is None:
            raise ValueError(
                "ChurnSim needs replicas=, shards= and/or edges=")
        self.replicas = replicas
        self.shards = shards           # a ShardedScheduler (or None)
        self.edges = edges             # an EdgeTier (or None)
        # the flight-recorder hook: dump the hub's ring to
        # <dump_on_fault>/fault-<step>-<kind>.jsonl after every fault step
        self.tel = tlm.resolve(telemetry)
        self.dump_on_fault = Path(dump_on_fault) if dump_on_fault else None
        if self.dump_on_fault is not None:
            self.dump_on_fault.mkdir(parents=True, exist_ok=True)
        self.rng = np.random.default_rng(seed)
        self.step = 0
        self.phase = "idle"
        self.in_flight: List[tuple[int, Dict[str, bytes]]] = []
        self.drop_next = 0
        self.events: List[tuple[int, str, object]] = []
        # (step, phase, member, primary_index at log time, record count)
        self.ingest_log: List[tuple[int, str, int, int, int]] = []
        if replicas is not None:
            replicas.transport = self._transport
            self._instrument()

    # -- instrumentation ---------------------------------------------------
    def _instrument(self) -> None:
        # wrap the Wire sink verb on each member *instance*; the deprecated
        # ingest shim calls self.recv, so shimmed callers are logged too
        for idx, member in enumerate(self.replicas.members):
            member.recv = self._wrap_recv(idx, member.recv)

    def _wrap_recv(self, idx: int, orig: Callable) -> Callable:
        def recv(records, *, client_id=None):
            self.ingest_log.append((self.step, self.phase, idx,
                                    self.replicas.primary_index,
                                    len(records)))
            return orig(records, client_id=client_id)
        return recv

    def _transport(self, peer_index: int, records: Dict[str, bytes]) -> bool:
        if peer_index in self.replicas._down:
            self._log("blackhole", peer_index)
            return False
        if self.drop_next > 0:
            self.drop_next -= 1
            self._log("drop", peer_index)
            return False
        self.in_flight.append((peer_index, records))
        self._log("send", peer_index)
        return True

    def _log(self, kind: str, detail: object) -> None:
        self.events.append((self.step, kind, detail))

    def dump(self, path) -> int:
        """Dump the telemetry flight recorder to ``path`` (JSONL)."""
        return self.tel.dump_jsonl(path)

    def _dump_fault(self, kind: str) -> None:
        if self.dump_on_fault is not None:
            self.dump(self.dump_on_fault / f"fault-{self.step:04d}-{kind}.jsonl")

    def _tick(self, phase: str) -> None:
        self.step += 1
        self.phase = phase

    def _need_replicas(self) -> ReplicaSet:
        if self.replicas is None:
            raise RuntimeError("this step needs replicas=; the sim was "
                               "built to drive scheduler shards only")
        return self.replicas

    # -- scripted steps ----------------------------------------------------
    def hot(self, fn: Callable[[], object]):
        """Run snapshot/training work as a hot-path step; any peer I/O in
        here is a failure the accounting will expose."""
        self._tick("hot")
        try:
            return fn()
        finally:
            self.phase = "idle"

    def pump(self, max_msgs: Optional[int] = None) -> int:
        self._need_replicas()
        self._tick("net")
        try:
            return self.replicas.pump(max_msgs)
        finally:
            self.phase = "idle"

    def deliver(self, shuffle: bool = True) -> int:
        """Deliver captured in-flight messages, scrambled (seeded) when
        ``shuffle`` — the reorder fault.  Chain-closure messages are
        self-contained, so any order must converge."""
        self._need_replicas()
        self._tick("net")
        try:
            msgs, self.in_flight = self.in_flight, []
            if shuffle and len(msgs) > 1:
                msgs = [msgs[i] for i in self.rng.permutation(len(msgs))]
            delivered = 0
            for peer_index, records in msgs:
                if peer_index in self.replicas._down:
                    self._log("lost", peer_index)
                    continue
                if self.replicas.deliver_direct(peer_index, records):
                    delivered += 1
            return delivered
        finally:
            self.phase = "idle"

    def drop(self, n: int = 1) -> None:
        """Discard the next ``n`` replication sends (retried next pump)."""
        self.drop_next += n

    def kill(self, index: int, wipe: bool = False) -> None:
        """Mark a member down; ``wipe`` simulates full disk loss."""
        self._need_replicas()
        self._tick("fault")
        self.replicas.mark_down(index)
        if wipe:
            self.replicas.members[index].wipe()
        self._log("kill", (index, wipe))
        self._dump_fault("kill")
        self.phase = "idle"

    def revive(self, index: int, sync: bool = False) -> None:
        self._need_replicas()
        self._tick("fault")
        self.replicas.mark_up(index)
        self._log("revive", index)
        self._dump_fault("revive")
        self.phase = "idle"
        if sync:
            self._tick("net")
            self.replicas.sync()
            self.deliver(shuffle=False)

    def promote(self, index: Optional[int] = None) -> int:
        self._need_replicas()
        self._tick("fault")
        if index is None:
            index = self.replicas.promote_best()
        else:
            self.replicas.promote(index)
        self._log("promote", index)
        self._dump_fault("promote")
        self.phase = "idle"
        return index

    # -- scheduler-shard churn --------------------------------------------
    def _need_shards(self):
        if self.shards is None:
            raise RuntimeError("sim was built without shards=")
        return self.shards

    def kill_shard(self, index: int) -> Dict[str, int]:
        """Kill scheduler shard ``index``: its key range and open units
        reassign deterministically to the survivors (fail_shard)."""
        shards = self._need_shards()
        self._tick("fault")
        info = shards.fail_shard(index)
        self._log("kill_shard", (index, info))
        self._dump_fault("kill_shard")
        self.phase = "idle"
        return info

    def random_shard_kill(self) -> Optional[int]:
        """Kill a seeded-random alive shard (never the last one); -> the
        killed index, or None when only one shard survives."""
        shards = self._need_shards()
        alive = shards.alive_shards()
        if len(alive) < 2:
            return None
        index = int(alive[self.rng.integers(len(alive))])
        self.kill_shard(index)
        return index

    def add_shard(self) -> int:
        """A new scheduler shard joins the plane and takes its share of
        range slots from the most-loaded owners; -> its index."""
        shards = self._need_shards()
        self._tick("fault")
        index = shards.add_shard()
        self._log("add_shard", index)
        self._dump_fault("add_shard")
        self.phase = "idle"
        return index

    def split_hot_shard(self) -> Optional[int]:
        """Split the hottest alive shard (largest open backlog,
        deterministic index tie-break) into the least-loaded one; -> the
        split shard's index, or None when there is nothing worth
        splitting (single alive shard, empty backlog, or the hot shard
        owns a single slot)."""
        shards = self._need_shards()
        alive = shards.alive_shards()
        if len(alive) < 2:
            return None
        hot = max(alive,
                  key=lambda i: (shards.shards[i].open_backlog(), -i))
        owned = sum(1 for o in shards._range_owner if o == hot)
        if shards.shards[hot].open_backlog() == 0 or owned < 2:
            return None
        self._tick("fault")
        info = shards.split_shard(hot)
        self._log("split_shard", (hot, info))
        self._dump_fault("split_shard")
        self.phase = "idle"
        return hot

    def rejoin_shard(self, index: int) -> Dict[str, int]:
        """A previously killed shard returns empty and earns slots back
        from the most-loaded owners."""
        shards = self._need_shards()
        self._tick("fault")
        info = shards.rejoin_shard(index)
        self._log("rejoin_shard", (index, info))
        self._dump_fault("rejoin_shard")
        self.phase = "idle"
        return info

    # -- edge-cache churn --------------------------------------------------
    def _need_edges(self):
        if self.edges is None:
            raise RuntimeError("this step needs edges=; the sim was built "
                               "without an EdgeTier")
        return self.edges

    def kill_cache(self, index: int, wipe: bool = False) -> None:
        """Kill edge cache ``index``: it drops out of discovery rankings
        immediately; ``wipe`` simulates disk loss as well."""
        edges = self._need_edges()
        self._tick("fault")
        edges.mark_down(index)
        if wipe:
            edges.members[index].invalidate()
        self._log("kill_cache", (index, wipe))
        self._dump_fault("kill_cache")
        self.phase = "idle"

    def revive_cache(self, index: int, stale: bool = False) -> None:
        """Revive edge cache ``index``.  ``stale`` drops its contents
        first — the cache re-enters rankings at zero coverage and must
        demand-fill before serving (the stale-cache churn case)."""
        edges = self._need_edges()
        self._tick("fault")
        if stale:
            edges.members[index].invalidate()
        edges.mark_up(index)
        self._log("revive_cache", (index, stale))
        self._dump_fault("revive_cache")
        self.phase = "idle"

    def random_cache_kill(self) -> Optional[int]:
        """Kill a seeded-random alive edge cache; -> the killed index, or
        None when no cache is alive."""
        edges = self._need_edges()
        alive = edges.alive_indices()
        if not alive:
            return None
        index = int(alive[self.rng.integers(len(alive))])
        self.kill_cache(index)
        return index

    def settle(self, max_rounds: int = 32) -> None:
        """Pump + deliver until the outbox and the wire are both empty."""
        for _ in range(max_rounds):
            if not self.replicas.outbox and not self.in_flight:
                return
            self.pump()
            self.deliver(shuffle=False)

    # -- accounting --------------------------------------------------------
    def peer_ingests_during_hot_steps(self) -> List[tuple]:
        """Log entries where a *non-primary* member did ingest I/O inside a
        hot step.  Must be empty: the snapshot hot path only enqueues."""
        return [e for e in self.ingest_log
                if e[1] == "hot" and e[2] != e[3]]
