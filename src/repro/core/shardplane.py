"""Sharded million-volunteer scheduler plane.

The paper's server is ONE machine; BOINC already pushes such a machine to
~8.8 M tasks/day, and V-BOINC predicts the server becomes the bottleneck
once capsule transfer is layered on.  ``ShardedScheduler`` splits the
control plane across N independent ``VolunteerScheduler`` shards while
presenting the exact ``request_work``/``report``/``drain_completed``
interface ``VBoincServer`` and ``VolunteerTrainer`` already speak.

Data flow — key range → shard → watermark queue:

1. **Key-range partitioning.**  The plane owns ``4*N`` contiguous
   *range slots*.  A volunteer's sha256 account-key hash picks its slot;
   ``_range_owner[slot]`` maps the slot to the shard that serves it (the
   indirection is what makes failover a table edit, not a re-hash of the
   fleet).  Work units stripe over the same slots by unit id, so each
   shard owns a disjoint set of units and volunteers mostly talk to one
   shard.
2. **Watermark refill (pytest-xdist ``LoadScheduling`` model).**  Each
   volunteer has a small local pending queue.  ``request_work`` pops from
   it in O(1); when the queue drops below ``watermark`` the plane refills
   a batch of ``refill_batch`` leases from the volunteer's home shard in
   ONE index scan — the scan cost amortizes over the batch, which is what
   keeps dispatch latency flat at millions of open units.
3. **Work stealing.**  A volunteer whose home shard is dry steals a batch
   from the *tail* of the largest open backlog among the other alive
   shards (newest units first, so thieves collide least with the owner's
   own head-first refills).  Only when every shard is dry does the
   volunteer get the home shard's exponential back-off.
4. **Batched quorum.**  ``report`` buffers results; ``flush_reports`` —
   called at most once per trainer round (from ``done``/``pending``/
   ``drain_completed``) or when the buffer hits ``report_batch_max`` —
   groups them by shard and validates quorum once per touched unit
   (``VolunteerScheduler.report_batch``) instead of once per result.
5. **Shard failover.**  ``fail_shard(i)`` (driven by the seeded
   ``ChurnSim``) deterministically reassigns the dead shard's range slots
   to the survivors, migrates its open units (results and lease history
   travel; leases drop and re-issue), merges its per-worker credit into
   each worker's new home shard, and preserves its completed log — no
   unit is lost, double-credited, or over-replicated across the move.
   ``tests/test_shardplane.py`` proves this differentially against a
   single-scheduler oracle under thousands of random interleavings.
6. **Elastic membership.**  Shard count grows and shrinks with demand
   (Anderson 2018's elastic control plane), all built on one reusable
   slot-handoff primitive, ``_migrate_slots(slots, target)`` — the
   generalized body of ``fail_shard``'s migration: slot ownership is a
   table edit, open units move with results + lease history intact
   (live leases drop, are counted, and re-issue on the target), and
   per-worker ledgers settle onto the new home so total minted credit
   is conserved through any join/split/kill/rejoin schedule.

   * ``add_shard()`` — a new ``VolunteerScheduler`` joins the plane and
     takes a fair share of slots from the currently most-loaded owners;
   * ``split_shard(i)`` — a hot shard hands off half of its slots
     (greedy backlog halving) to the least-loaded peer;
   * ``rejoin_shard(i)`` — a killed shard returns empty and earns its
     share of slots back through the same take-from-the-loaded path;
   * slot placement everywhere (including failover) is backlog-aware
     greedy bin packing, replacing the old ``slot % survivors``
     round-robin, and the steal policy picks its victim by per-shard
     *request rate* (demand tracked in the telemetry scope per refill
     window) relative to backlog — an oversupplied shard with no live
     requesters is robbed before a busy one with a deep queue.

   Every handoff traces ``slot_handoff``/``shard_join`` events stamped
   with ``cause=``/``cause_seq=`` at the source, and the randomized
   oracle-differential harness drives full join/split/kill/rejoin
   schedules byte-identically against the single-scheduler oracle.
"""
from __future__ import annotations

import hashlib
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from repro.core import telemetry as tlm
from repro.core.scheduler import (SimClock, VolunteerScheduler, WorkerInfo,
                                  WorkUnit)

SLOTS_PER_SHARD = 4      # range slots per shard: granularity of failover


def key_hash(worker_id: str) -> int:
    """Stable account-key hash (sha256, like the server's account keys —
    NOT Python's salted hash())."""
    return int.from_bytes(
        hashlib.sha256(worker_id.encode()).digest()[:8], "big")


class _UnitsView:
    """Read-only mapping over every shard's units, routed by the plane's
    unit→shard index — lets trainer/server code written against
    ``scheduler.units`` run unchanged."""

    def __init__(self, plane: "ShardedScheduler"):
        self._plane = plane

    def get(self, unit_id: int, default=None) -> Optional[WorkUnit]:
        sidx = self._plane._unit_shard.get(unit_id)
        if sidx is None:
            return default
        return self._plane.shards[sidx].units.get(unit_id, default)

    def __getitem__(self, unit_id: int) -> WorkUnit:
        wu = self.get(unit_id)
        if wu is None:
            raise KeyError(unit_id)
        return wu

    def __contains__(self, unit_id: int) -> bool:
        return self.get(unit_id) is not None

    def __len__(self) -> int:
        return sum(len(s.units) for s in self._plane.shards)

    def __iter__(self) -> Iterator[int]:
        for s in self._plane.shards:
            yield from s.units

    def items(self):
        for s in self._plane.shards:
            yield from s.units.items()

    def values(self):
        for s in self._plane.shards:
            yield from s.units.values()


class ShardedScheduler:
    """N ``VolunteerScheduler`` shards behind the single-scheduler API."""

    def __init__(self, *, shards: int = 4, replication: int = 1,
                 quorum: int = 1, deadline_s: float = 60.0,
                 backoff_base_s: float = 0.5, backoff_max_s: float = 60.0,
                 straggler_factor: float = 0.8, max_extra_results: int = 4,
                 clock=time.time, watermark: int = 2, refill_batch: int = 8,
                 steal: bool = True, report_batch_max: int = 1024,
                 telemetry: Optional[tlm.Telemetry] = None):
        if shards < 1:
            raise ValueError("need at least one shard")
        self.tel = tlm.resolve(telemetry)
        self.n_shards = shards
        self.replication = replication
        self.quorum = quorum
        self.deadline_s = deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.straggler_factor = straggler_factor
        self.max_extra_results = max_extra_results
        self.clock = clock
        self.watermark = watermark
        self.refill_batch = max(refill_batch, 1)
        self.steal = steal
        self.report_batch_max = report_batch_max
        self.shards = [self._new_shard(i) for i in range(shards)]
        self.n_slots = SLOTS_PER_SHARD * shards
        # range slot -> owning shard; failover rewrites entries in place
        self._range_owner: List[int] = [i % shards
                                        for i in range(self.n_slots)]
        self.shard_alive: List[bool] = [True] * shards
        self._unit_shard: Dict[int, int] = {}      # unit -> current shard
        self._home_cache: Dict[str, int] = {}      # worker -> slot
        # per-volunteer low-watermark pending queue: (shard_idx, unit_id)
        self._queues: Dict[str, Deque[Tuple[int, int]]] = {}
        # buffered (worker, unit, hash) reports awaiting the round flush
        self._report_buf: List[Tuple[str, int, str]] = []
        # completion log preserved across shard failover migrations
        self._migrated_completed: List[tuple[int, str]] = []
        self.units = _UnitsView(self)
        scope = self.tel.scope("shardplane")
        self._scope = scope
        self.metrics = scope.counters(
            "refills", "refill_units", "steals", "steal_units",
            "shard_kills", "shard_joins", "shard_splits", "slot_handoffs",
            "migrated_units", "report_flushes")
        self.plane_stats = scope.view()
        self._flush_hist = scope.histogram("report_flush_size",
                                           tlm.SIZE_BUCKETS)
        self._dispatch_hist = scope.histogram("dispatch_latency_s",
                                              tlm.TIME_BUCKETS_S)
        # per-shard demand signal for the steal policy: home-routed
        # request counts live in the telemetry scope; the mark snapshots
        # each counter at the last report flush, so (value - mark) is the
        # request rate over the current refill window
        self._shard_req = [scope.counter(f"requests_shard{i}")
                           for i in range(shards)]
        self._req_mark = [0] * shards

    def _new_shard(self, index: int) -> VolunteerScheduler:
        return VolunteerScheduler(
            replication=self.replication, quorum=self.quorum,
            deadline_s=self.deadline_s, backoff_base_s=self.backoff_base_s,
            backoff_max_s=self.backoff_max_s,
            straggler_factor=self.straggler_factor,
            max_extra_results=self.max_extra_results, clock=self.clock,
            telemetry=self.tel, shard_id=index)

    # ---------------- key-range routing ----------------
    def slot_of(self, worker_id: str) -> int:
        slot = self._home_cache.get(worker_id)
        if slot is None:
            slot = key_hash(worker_id) % self.n_slots
            self._home_cache[worker_id] = slot
        return slot

    def home_shard(self, worker_id: str) -> int:
        return self._range_owner[self.slot_of(worker_id)]

    def unit_slot(self, unit_id: int) -> int:
        return unit_id % self.n_slots

    # ---------------- membership (elastic) ----------------
    def join(self, worker_id: str) -> WorkerInfo:
        return self.shards[self.home_shard(worker_id)].join(worker_id)

    def leave(self, worker_id: str) -> None:
        # the worker may hold leases on foreign shards (stealing): drop
        # them everywhere it has state
        for s in self.shards:
            if worker_id in s.workers or worker_id in s._worker_leases:
                s.leave(worker_id)
        self._queues.pop(worker_id, None)

    # ---------------- unit lifecycle ----------------
    def submit(self, unit_id: int, payload: dict, *,
               replication: Optional[int] = None,
               quorum: Optional[int] = None) -> WorkUnit:
        prev = self._unit_shard.get(unit_id)
        sidx = self._range_owner[self.unit_slot(unit_id)]
        if prev is not None and prev != sidx:
            wu_prev = self.shards[prev].units.get(unit_id)
            if wu_prev is not None and not wu_prev.completed:
                # resubmit of a unit that migrated to a non-home shard:
                # keep it where it lives so the open entry is reused
                sidx = prev
        self._unit_shard[unit_id] = sidx
        return self.shards[sidx].submit(unit_id, payload,
                                        replication=replication,
                                        quorum=quorum)

    # ---------------- dispatch: watermark queue + stealing -------------
    def _valid_entry(self, worker_id: str, sidx: int, unit_id: int) -> bool:
        # a queued lease may have expired/migrated/completed since refill
        if self._unit_shard.get(unit_id) != sidx:
            return False
        wu = self.shards[sidx].units.get(unit_id)
        return (wu is not None and not wu.completed
                and worker_id in wu.leases)

    def _refill(self, worker_id: str, q: Deque[Tuple[int, int]],
                now: float) -> None:
        # size the refill from *valid* queue entries only: after churn
        # (expiry, migration, completion) the queue holds entries that
        # `_valid_entry` will discard on pop, and counting them made
        # every post-churn refill chronically short
        if q:
            live = [e for e in q if self._valid_entry(worker_id, *e)]
            if len(live) != len(q):
                q.clear()
                q.extend(live)
        want = self.watermark + self.refill_batch - len(q)
        if want <= 0:
            return
        home = self.home_shard(worker_id)
        got = self.shards[home].request_batch(worker_id, want)
        if got:
            self.metrics.refills.inc()
            self.metrics.refill_units.inc(len(got))
            if self.tel.tracing:
                self.tel.event("refill", worker=worker_id, shard=home,
                               n=len(got))
            q.extend((home, wu.unit_id) for wu in got)
            return
        if not self.steal:
            return
        victim = self._steal_victim(home)
        if victim < 0:
            return
        got = self.shards[victim].request_batch(worker_id, want, tail=True)
        if got:
            self.metrics.steals.inc()
            self.metrics.steal_units.inc(len(got))
            if self.tel.tracing:
                self.tel.event("steal", worker=worker_id, shard=victim,
                               n=len(got), home=home)
            q.extend((victim, wu.unit_id) for wu in got)

    def _steal_victim(self, home: int) -> int:
        """Pick the shard to steal from: highest open backlog *per unit
        of demand* (home-routed requests since the last report flush),
        not raw backlog size.  An oversupplied shard whose volunteers
        went quiet is robbed before a busy shard whose deep queue is
        already being drained by its own population.  Deterministic:
        ties break by raw backlog, then lowest index."""
        victim, best = -1, None
        for i, s in enumerate(self.shards):
            if i == home or not self.shard_alive[i]:
                continue
            backlog = s.open_backlog()
            if backlog <= 0:
                continue
            rate = self._shard_req[i].value - self._req_mark[i]
            key = (backlog / (1.0 + rate), backlog, -i)
            if best is None or key > best:
                victim, best = i, key
        return victim

    def request_work(self, worker_id: str) -> Optional[WorkUnit]:
        """O(1) pop from the volunteer's watermark queue; batch refill
        (then steal) only when the queue runs low."""
        if not self.tel.tracing:
            return self._request_work(worker_id)
        t0 = time.perf_counter()
        wu = self._request_work(worker_id)
        self._dispatch_hist.observe(time.perf_counter() - t0)
        return wu

    def _request_work(self, worker_id: str) -> Optional[WorkUnit]:
        now = self.clock()
        home_idx = self.home_shard(worker_id)
        self._shard_req[home_idx].inc()    # demand signal for stealing
        home = self.shards[home_idx]
        info = home.join(worker_id)
        if now < info.backoff_until:
            home.metrics.rejected_requests.inc()
            return None
        q = self._queues.setdefault(worker_id, deque())
        refilled = len(q) < self.watermark
        if refilled:
            self._refill(worker_id, q, now)
        while q:
            sidx, unit_id = q.popleft()
            if self._valid_entry(worker_id, sidx, unit_id):
                return self.shards[sidx].units[unit_id]
        if not refilled:
            # the queue *looked* stocked but churn (expiry, migration,
            # completion) had invalidated every entry — refill now, at
            # full size, instead of bouncing the volunteer into backoff
            self._refill(worker_id, q, now)
            while q:
                sidx, unit_id = q.popleft()
                if self._valid_entry(worker_id, sidx, unit_id):
                    return self.shards[sidx].units[unit_id]
        # every refill source is dry: exponential back-off on the home
        # shard (only a successful dispatch resets it)
        home.backoff(worker_id, now)
        return None

    # ---------------- results: per-round batched quorum ----------------
    def report(self, worker_id: str, unit_id: int, result_hash: str) -> bool:
        """Buffer the result; quorum validates at the next round flush.

        -> True only when this call's flush completed the unit (callers
        needing completion should watch ``drain_completed``, as the
        trainer already does)."""
        self._report_buf.append((worker_id, unit_id, result_hash))
        if len(self._report_buf) >= self.report_batch_max:
            done = self.flush_reports()
            return any(uid == unit_id for uid, _ in done)
        return False

    def flush_reports(self) -> List[tuple[int, str]]:
        """Apply buffered results grouped by shard, one quorum check per
        touched unit per shard (``report_batch``)."""
        if not self._report_buf:
            return []
        buf, self._report_buf = self._report_buf, []
        by_shard: Dict[int, List[Tuple[str, int, str]]] = {}
        for worker_id, unit_id, h in buf:
            sidx = self._unit_shard.get(unit_id)
            if sidx is None:
                continue               # unknown unit: drop silently
            by_shard.setdefault(sidx, []).append((worker_id, unit_id, h))
        done: List[tuple[int, str]] = []
        for sidx, reports in by_shard.items():
            done.extend(self.shards[sidx].report_batch(reports))
        self.metrics.report_flushes.inc()
        self._flush_hist.observe(len(buf))
        # roll the request-rate window: (counter - mark) measures demand
        # since the last flush, the steal policy's denominator
        for i, c in enumerate(self._shard_req):
            self._req_mark[i] = c.value
        return done

    # ---------------- progress ----------------
    def open_backlog(self) -> int:
        return sum(s.open_backlog() for s in self.shards)

    def done(self) -> bool:
        self.flush_reports()
        return self.open_backlog() == 0

    def pending(self) -> List[WorkUnit]:
        self.flush_reports()
        out: List[WorkUnit] = []
        for s in self.shards:
            out.extend(s.pending())
        return out

    def drain_completed(self) -> List[tuple[int, str]]:
        self.flush_reports()
        out = self._migrated_completed
        self._migrated_completed = []
        for s in self.shards:
            out.extend(s.drain_completed())
        return out

    def canonical_results(self) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for s in self.shards:
            out.update(s.canonical_results())
        return out

    def _expire_leases(self, now: float) -> None:
        for i, s in enumerate(self.shards):
            if self.shard_alive[i]:
                s._expire_leases(now)

    # ---------------- credit ----------------
    def credit_transfer(self, worker_id: str, moved_bytes: int,
                        dedup_bytes: int = 0) -> None:
        self.shards[self.home_shard(worker_id)].credit_transfer(
            worker_id, moved_bytes, dedup_bytes)

    @property
    def workers(self) -> Dict[str, WorkerInfo]:
        """Merged per-worker view (a worker that stole work has state on
        several shards); credit/counters sum, alive ORs."""
        merged: Dict[str, WorkerInfo] = {}
        for s in self.shards:
            for wid, info in s.workers.items():
                m = merged.get(wid)
                if m is None:
                    merged[wid] = m = WorkerInfo(wid, info.joined)
                    m.alive = False
                m.credit += info.credit
                m.completed += info.completed
                m.invalid += info.invalid
                m.uplink_bytes += info.uplink_bytes
                m.uplink_dedup += info.uplink_dedup
                m.alive = m.alive or info.alive
                m.backoff_until = max(m.backoff_until, info.backoff_until)
        return merged

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.stats.items():
                agg[k] = agg.get(k, 0) + v
        agg.update(self.plane_stats)
        agg["shards"] = self.n_shards
        agg["shards_alive"] = sum(self.shard_alive)
        return agg

    # ---------------- elastic membership: slot handoff ----------------
    def alive_shards(self) -> List[int]:
        return [i for i, a in enumerate(self.shard_alive) if a]

    def _slot_backlog(self) -> Dict[int, int]:
        """Open-unit count per range slot (the placement weight)."""
        out: Dict[int, int] = {}
        for s in self.shards:
            for uid, wu in s.units.items():
                if not wu.completed:
                    slot = self.unit_slot(uid)
                    out[slot] = out.get(slot, 0) + 1
        return out

    def _place_slots(self, slots: List[int],
                     candidates: List[int]) -> Dict[int, List[int]]:
        """Backlog-aware slot placement (replaces ``slot % survivors``):
        greedy bin packing — heaviest slot first, each to the candidate
        with the smallest projected backlog.  Fully deterministic (ties
        break by slot, then candidate index)."""
        slot_load = self._slot_backlog()
        load = {c: float(self.shards[c].open_backlog()) for c in candidates}
        placement: Dict[int, List[int]] = {c: [] for c in candidates}
        for slot in sorted(slots, key=lambda s: (-slot_load.get(s, 0), s)):
            tgt = min(candidates, key=lambda c: (load[c], c))
            placement[tgt].append(slot)
            load[tgt] += slot_load.get(slot, 0)
        return placement

    def _move_unit(self, unit_id: int, wu: WorkUnit,
                   src: VolunteerScheduler, src_idx: int, target_idx: int,
                   totals: Dict[str, int], *, cause: str,
                   cause_seq: int) -> None:
        """Move one unit to ``target_idx``: results + lease history +
        escalation counters travel; live leases drop (counted, traced
        with their cause) and re-issue on the target; every worker in
        the lease history gets a ledger slot there so a late report from
        a pre-move lease holder still settles its credit."""
        tel = self.tel
        target = self.shards[target_idx]
        self._unit_shard[unit_id] = target_idx
        if wu.completed:
            target.units[unit_id] = wu
            totals["copied_completed"] += 1
            return
        totals["dropped_leases"] += len(wu.leases)
        src.metrics.dropped_leases.inc(len(wu.leases))
        for wid in wu.leases:
            src._worker_leases.get(wid, {}).pop(unit_id, None)
            if tel.tracing:
                tel.event("lease_drop", unit=unit_id, worker=wid,
                          shard=src_idx, cause=cause, cause_seq=cause_seq)
        wu.leases.clear()              # heap/mirror entries go stale
        wu.straggler_issued = False
        target.units[unit_id] = wu
        target._open.append(unit_id)
        target._n_open += 1
        totals["reassigned_open"] += 1
        if tel.tracing:
            tel.event("migrate", unit=unit_id, shard=target_idx,
                      from_shard=src_idx, cause=cause, cause_seq=cause_seq)
        for wid in wu.ever_leased:
            if wid not in target.workers:
                s = src.workers.get(wid)
                ghost = WorkerInfo(wid, s.joined if s else 0.0)
                ghost.alive = s.alive if s else False
                target.workers[wid] = ghost

    def _settle_ledger(self, src: VolunteerScheduler,
                       target: VolunteerScheduler, wid: str) -> None:
        """A worker's home slot moved: its credit/counters settle onto
        the new home shard.  The source keeps a zeroed record (it may
        still hold the worker's leases on unmoved units), so the merged
        ``workers`` view conserves every counter."""
        info = src.workers[wid]
        m = target.workers.get(wid)
        if m is None:
            m = WorkerInfo(wid, info.joined)
            m.alive = info.alive
            target.workers[wid] = m
        else:
            m.alive = m.alive or info.alive
        m.credit += info.credit
        m.completed += info.completed
        m.invalid += info.invalid
        m.uplink_bytes += info.uplink_bytes
        m.uplink_dedup += info.uplink_dedup
        m.backoff_until = max(m.backoff_until, info.backoff_until)
        m.backoff_k = max(m.backoff_k, info.backoff_k)
        info.credit = 0.0
        info.completed = info.invalid = 0
        info.uplink_bytes = info.uplink_dedup = 0

    def _migrate_slots(self, slots: List[int], target_idx: int, *,
                       cause: str, cause_seq: int = 0,
                       settle_ledgers: bool = True) -> Dict[str, int]:
        """The reusable handoff primitive under failover, join, split and
        rejoin: move ownership of ``slots`` to shard ``target_idx`` and
        migrate every resident unit from its current owner, exactly as
        failover does — open units travel with results + lease history,
        live leases drop and re-issue, completed units copy so late
        reports still see them, and (``settle_ledgers``) per-worker
        ledgers of workers homed on the moved slots settle onto the
        target.  ``fail_shard`` passes ``settle_ledgers=False`` and does
        its own full-worker merge, since the whole source retires."""
        tel = self.tel
        slots = [s for s in slots if self._range_owner[s] != target_idx]
        totals = {"slots": len(slots), "reassigned_open": 0,
                  "copied_completed": 0, "dropped_leases": 0}
        if not slots:
            return totals
        by_owner: Dict[int, List[int]] = {}
        for slot in slots:
            owner = self._range_owner[slot]
            by_owner.setdefault(owner, []).append(slot)
            self._range_owner[slot] = target_idx
            self.metrics.slot_handoffs.inc()
            if tel.tracing:
                tel.event("slot_handoff", shard=target_idx, slot=slot,
                          from_shard=owner, cause=cause,
                          cause_seq=cause_seq)
        for src_idx in sorted(by_owner):
            src = self.shards[src_idx]
            moved_slots = set(by_owner[src_idx])
            moved_uids = [uid for uid in src.units
                          if self.unit_slot(uid) in moved_slots
                          and self._unit_shard.get(uid) == src_idx]
            for uid in moved_uids:
                self._move_unit(uid, src.units[uid], src, src_idx,
                                target_idx, totals, cause=cause,
                                cause_seq=cause_seq)
                del src.units[uid]
            if moved_uids:
                # the source stays live: rebuild its open index without
                # the departed units (its lease heap self-heals lazily)
                src._open = deque(u for u in src._open if u in src.units
                                  and not src.units[u].completed)
                src._open_stale = 0
                src._n_open = len(src._open)
            if settle_ledgers:
                for wid in sorted(src.workers):
                    if self.slot_of(wid) in moved_slots:
                        self._settle_ledger(src, self.shards[target_idx],
                                            wid)
        self.metrics.migrated_units.inc(totals["reassigned_open"])
        return totals

    def _take_slots(self, target_idx: int, n: int, *, cause: str,
                    cause_seq: int = 0) -> Dict[str, int]:
        """A joining/rejoining shard earns ``n`` slots: repeatedly take
        the heaviest slot from the currently most-loaded other owner
        (each owner keeps at least one slot).  Deterministic."""
        slot_load = self._slot_backlog()
        owned: Dict[int, List[int]] = {}
        for slot, owner in enumerate(self._range_owner):
            if owner != target_idx and self.shard_alive[owner]:
                owned.setdefault(owner, []).append(slot)
        load = {i: float(self.shards[i].open_backlog()) for i in owned}
        taken: List[int] = []
        for _ in range(n):
            donors = [i for i, sl in owned.items() if len(sl) > 1]
            if not donors:
                break
            donor = max(donors, key=lambda i: (load[i], -i))
            slot = max(owned[donor],
                       key=lambda s: (slot_load.get(s, 0), -s))
            owned[donor].remove(slot)
            load[donor] -= slot_load.get(slot, 0)
            taken.append(slot)
        return self._migrate_slots(taken, target_idx, cause=cause,
                                   cause_seq=cause_seq)

    # ---------------- elastic membership: join / split / rejoin --------
    def add_shard(self) -> int:
        """A new ``VolunteerScheduler`` joins the plane and takes its
        fair share of range slots from the most-loaded owners; -> the
        new shard's index."""
        self.flush_reports()
        index = len(self.shards)
        self.shards.append(self._new_shard(index))
        self.shard_alive.append(True)
        self.n_shards += 1
        self._shard_req.append(self._scope.counter(f"requests_shard{index}"))
        self._req_mark.append(0)
        self.metrics.shard_joins.inc()
        jseq = self.tel.event("shard_join", shard=index,
                              cause="add_shard") if self.tel.tracing else 0
        share = self.n_slots // len(self.alive_shards())
        info = self._take_slots(index, share, cause="shard_join",
                                cause_seq=jseq)
        if self.tel.tracing:
            self.tel.event("rebalance", shard=index, cause="shard_join",
                           cause_seq=jseq, **info)
        return index

    def split_shard(self, index: int,
                    target: Optional[int] = None) -> Dict[str, int]:
        """Split a hot shard: hand off half of its slots (greedy backlog
        halving — the heavier half of each pair leaves) to ``target``,
        default the least-loaded other alive shard.  Open units, lease
        history and per-worker ledgers travel exactly as failover moves
        them; -> handoff summary."""
        if not self.shard_alive[index]:
            raise ValueError(f"cannot split dead shard {index}")
        owned = [s for s, o in enumerate(self._range_owner) if o == index]
        if len(owned) < 2:
            raise ValueError(f"shard {index} owns {len(owned)} slot(s); "
                             f"nothing to split")
        others = [i for i in self.alive_shards() if i != index]
        if not others:
            raise ValueError("cannot split the only alive shard")
        if target is None:
            target = min(others,
                         key=lambda i: (self.shards[i].open_backlog(), i))
        if target == index or not self.shard_alive[target]:
            raise ValueError(f"bad split target {target}")
        self.flush_reports()
        self.metrics.shard_splits.inc()
        sseq = self.tel.event("shard_split", shard=index,
                              target=target) if self.tel.tracing else 0
        # greedy halving by backlog: heaviest slot first, each to the
        # currently lighter half; the kept half gets the first (hottest)
        slot_load = self._slot_backlog()
        keep_w = give_w = 0
        give: List[int] = []
        for slot in sorted(owned,
                           key=lambda s: (-slot_load.get(s, 0), s)):
            if give_w < keep_w or (give_w == keep_w
                                   and len(give) * 2 < len(owned) - 1):
                give.append(slot)
                give_w += slot_load.get(slot, 0)
            else:
                keep_w += slot_load.get(slot, 0)
        if not give:                       # all load on one slot: still
            give = [owned[-1]]             # hand off a coldest slot
        info = self._migrate_slots(give, target, cause="shard_split",
                                   cause_seq=sseq)
        info["split"] = index
        info["target"] = target
        return info

    def rejoin_shard(self, index: int) -> Dict[str, int]:
        """A killed shard returns: it comes back *empty* (its state was
        retired at failover) and earns its share of slots back from the
        most-loaded owners; -> handoff summary."""
        if self.shard_alive[index]:
            raise ValueError(f"shard {index} is already alive")
        self.flush_reports()
        self.shard_alive[index] = True
        self.metrics.shard_joins.inc()
        jseq = self.tel.event("shard_join", shard=index,
                              cause="rejoin") if self.tel.tracing else 0
        share = self.n_slots // len(self.alive_shards())
        info = self._take_slots(index, share, cause="shard_rejoin",
                                cause_seq=jseq)
        if self.tel.tracing:
            self.tel.event("rebalance", shard=index, cause="shard_rejoin",
                           cause_seq=jseq, **info)
        return info

    def rebalance(self, *, factor: float = 2.0,
                  min_backlog: int = 16) -> Optional[Dict[str, int]]:
        """One elastic-policy step (the ``--rebalance`` hook): when the
        hottest alive shard's open backlog exceeds ``factor``× the
        coldest's and ``min_backlog``, split it into the coldest; ->
        the split summary, or None when balanced."""
        alive = self.alive_shards()
        if len(alive) < 2:
            return None
        hot = max(alive, key=lambda i: (self.shards[i].open_backlog(), -i))
        cold = min(alive, key=lambda i: (self.shards[i].open_backlog(), i))
        hb = self.shards[hot].open_backlog()
        cb = self.shards[cold].open_backlog()
        if hot == cold or hb < min_backlog or hb <= factor * max(cb, 1):
            return None
        if sum(1 for o in self._range_owner if o == hot) < 2:
            return None
        return self.split_shard(hot, target=cold)

    # ---------------- failover ----------------
    def fail_shard(self, index: int) -> Dict[str, int]:
        """Kill shard ``index``: reassign its key-range slots to the
        survivors (backlog-aware placement) and migrate its state
        through the same ``_migrate_slots`` primitive joins and splits
        use.

        * open units move to the new owner of their range slot — results,
          lease history (``ever_leased``) and escalation counters travel,
          live leases drop (counted) and re-issue on the target;
        * completed units copy over so late reports and credit settling
          still see them; the un-drained completion log is preserved;
        * per-worker credit/counters merge into each worker's *new* home
          shard — total minted credit is conserved.

        -> migration summary dict."""
        if not self.shard_alive[index]:
            raise ValueError(f"shard {index} is already down")
        survivors = [i for i in self.alive_shards() if i != index]
        if not survivors:
            raise ValueError("cannot kill the last alive shard")
        # drain the report inbox first: buffered results must apply where
        # their workers are joined, or their credit share would vanish
        # when the unit completes on a shard that never saw the worker
        self.flush_reports()
        self.shard_alive[index] = False
        self.metrics.shard_kills.inc()
        tel = self.tel
        kseq = tel.event("kill_shard", shard=index) if tel.tracing else 0
        dead = self.shards[index]
        # preserve completions that were not yet drained
        self._migrated_completed.extend(dead.drain_completed())
        owned = [s for s, o in enumerate(self._range_owner) if o == index]
        placement = self._place_slots(owned, survivors)
        totals = {"reassigned_open": 0, "copied_completed": 0,
                  "dropped_leases": 0}
        for tgt in sorted(placement):
            info = self._migrate_slots(placement[tgt], tgt,
                                       cause="shard_kill", cause_seq=kseq,
                                       settle_ledgers=False)
            for k in totals:
                totals[k] += info[k]
        # stragglers: units resident here whose slot is owned elsewhere
        # (kept in place by an earlier migration) move to their owner
        for uid in list(dead.units):
            self._move_unit(uid, dead.units[uid], dead, index,
                            self._range_owner[self.unit_slot(uid)],
                            totals, cause="shard_kill", cause_seq=kseq)
            del dead.units[uid]
        # merge volunteer accounting into each worker's new home shard
        for wid, info in dead.workers.items():
            home = self.shards[self.home_shard(wid)]
            m = home.workers.get(wid)
            if m is None or not m.alive:
                m = home.join(wid) if info.alive else \
                    home.workers.setdefault(wid, WorkerInfo(wid, info.joined))
                m.alive = info.alive
            m.credit += info.credit
            m.completed += info.completed
            m.invalid += info.invalid
            m.uplink_bytes += info.uplink_bytes
            m.uplink_dedup += info.uplink_dedup
            m.backoff_until = max(m.backoff_until, info.backoff_until)
            m.backoff_k = max(m.backoff_k, info.backoff_k)
        # retire the dead shard's state so aggregate stats don't double
        # count workers and the view classes skip it
        dead.units = {}
        dead._open.clear()
        dead._open_stale = 0
        dead._n_open = 0
        dead._lease_heap.clear()
        dead._worker_leases.clear()
        dead.workers = {}
        return totals

    def shard_report(self) -> List[Dict[str, int]]:
        """Per-shard load view (benchmarks / ops)."""
        return [{"shard": i, "alive": int(self.shard_alive[i]),
                 "open": s.open_backlog(), "workers": len(s.workers),
                 "dispatched": s.stats["dispatched"],
                 "completed": s.stats["completed"]}
                for i, s in enumerate(self.shards)]
