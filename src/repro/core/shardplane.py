"""Sharded million-volunteer scheduler plane.

The paper's server is ONE machine; BOINC already pushes such a machine to
~8.8 M tasks/day, and V-BOINC predicts the server becomes the bottleneck
once capsule transfer is layered on.  ``ShardedScheduler`` splits the
control plane across N independent ``VolunteerScheduler`` shards while
presenting the exact ``request_work``/``report``/``drain_completed``
interface ``VBoincServer`` and ``VolunteerTrainer`` already speak.

Data flow — key range → shard → watermark queue:

1. **Key-range partitioning.**  The plane owns ``4*N`` contiguous
   *range slots*.  A volunteer's sha256 account-key hash picks its slot;
   ``_range_owner[slot]`` maps the slot to the shard that serves it (the
   indirection is what makes failover a table edit, not a re-hash of the
   fleet).  Work units stripe over the same slots by unit id, so each
   shard owns a disjoint set of units and volunteers mostly talk to one
   shard.
2. **Watermark refill (pytest-xdist ``LoadScheduling`` model).**  Each
   volunteer has a small local pending queue.  ``request_work`` pops from
   it in O(1); when the queue drops below ``watermark`` the plane refills
   a batch of ``refill_batch`` leases from the volunteer's home shard in
   ONE index scan — the scan cost amortizes over the batch, which is what
   keeps dispatch latency flat at millions of open units.
3. **Work stealing.**  A volunteer whose home shard is dry steals a batch
   from the *tail* of the largest open backlog among the other alive
   shards (newest units first, so thieves collide least with the owner's
   own head-first refills).  Only when every shard is dry does the
   volunteer get the home shard's exponential back-off.
4. **Batched quorum.**  ``report`` buffers results; ``flush_reports`` —
   called at most once per trainer round (from ``done``/``pending``/
   ``drain_completed``) or when the buffer hits ``report_batch_max`` —
   groups them by shard and validates quorum once per touched unit
   (``VolunteerScheduler.report_batch``) instead of once per result.
5. **Shard failover.**  ``fail_shard(i)`` (driven by the seeded
   ``ChurnSim``) deterministically reassigns the dead shard's range slots
   to the survivors, migrates its open units (results and lease history
   travel; leases drop and re-issue), merges its per-worker credit into
   each worker's new home shard, and preserves its completed log — no
   unit is lost, double-credited, or over-replicated across the move.
   ``tests/test_shardplane.py`` proves this differentially against a
   single-scheduler oracle under thousands of random interleavings.
"""
from __future__ import annotations

import hashlib
import time
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional, Tuple

from repro.core import telemetry as tlm
from repro.core.scheduler import (SimClock, VolunteerScheduler, WorkerInfo,
                                  WorkUnit)

SLOTS_PER_SHARD = 4      # range slots per shard: granularity of failover


def key_hash(worker_id: str) -> int:
    """Stable account-key hash (sha256, like the server's account keys —
    NOT Python's salted hash())."""
    return int.from_bytes(
        hashlib.sha256(worker_id.encode()).digest()[:8], "big")


class _UnitsView:
    """Read-only mapping over every shard's units, routed by the plane's
    unit→shard index — lets trainer/server code written against
    ``scheduler.units`` run unchanged."""

    def __init__(self, plane: "ShardedScheduler"):
        self._plane = plane

    def get(self, unit_id: int, default=None) -> Optional[WorkUnit]:
        sidx = self._plane._unit_shard.get(unit_id)
        if sidx is None:
            return default
        return self._plane.shards[sidx].units.get(unit_id, default)

    def __getitem__(self, unit_id: int) -> WorkUnit:
        wu = self.get(unit_id)
        if wu is None:
            raise KeyError(unit_id)
        return wu

    def __contains__(self, unit_id: int) -> bool:
        return self.get(unit_id) is not None

    def __len__(self) -> int:
        return sum(len(s.units) for s in self._plane.shards)

    def __iter__(self) -> Iterator[int]:
        for s in self._plane.shards:
            yield from s.units

    def items(self):
        for s in self._plane.shards:
            yield from s.units.items()

    def values(self):
        for s in self._plane.shards:
            yield from s.units.values()


class ShardedScheduler:
    """N ``VolunteerScheduler`` shards behind the single-scheduler API."""

    def __init__(self, *, shards: int = 4, replication: int = 1,
                 quorum: int = 1, deadline_s: float = 60.0,
                 backoff_base_s: float = 0.5, backoff_max_s: float = 60.0,
                 straggler_factor: float = 0.8, max_extra_results: int = 4,
                 clock=time.time, watermark: int = 2, refill_batch: int = 8,
                 steal: bool = True, report_batch_max: int = 1024,
                 telemetry: Optional[tlm.Telemetry] = None):
        if shards < 1:
            raise ValueError("need at least one shard")
        self.tel = tlm.resolve(telemetry)
        self.n_shards = shards
        self.replication = replication
        self.quorum = quorum
        self.deadline_s = deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.clock = clock
        self.watermark = watermark
        self.refill_batch = max(refill_batch, 1)
        self.steal = steal
        self.report_batch_max = report_batch_max
        self.shards = [VolunteerScheduler(
            replication=replication, quorum=quorum, deadline_s=deadline_s,
            backoff_base_s=backoff_base_s, backoff_max_s=backoff_max_s,
            straggler_factor=straggler_factor,
            max_extra_results=max_extra_results, clock=clock,
            telemetry=self.tel, shard_id=i)
            for i in range(shards)]
        self.n_slots = SLOTS_PER_SHARD * shards
        # range slot -> owning shard; failover rewrites entries in place
        self._range_owner: List[int] = [i % shards
                                        for i in range(self.n_slots)]
        self.shard_alive: List[bool] = [True] * shards
        self._unit_shard: Dict[int, int] = {}      # unit -> current shard
        self._home_cache: Dict[str, int] = {}      # worker -> slot
        # per-volunteer low-watermark pending queue: (shard_idx, unit_id)
        self._queues: Dict[str, Deque[Tuple[int, int]]] = {}
        # buffered (worker, unit, hash) reports awaiting the round flush
        self._report_buf: List[Tuple[str, int, str]] = []
        # completion log preserved across shard failover migrations
        self._migrated_completed: List[tuple[int, str]] = []
        self.units = _UnitsView(self)
        scope = self.tel.scope("shardplane")
        self.metrics = scope.counters(
            "refills", "refill_units", "steals", "steal_units",
            "shard_kills", "migrated_units", "report_flushes")
        self.plane_stats = scope.view()
        self._flush_hist = scope.histogram("report_flush_size",
                                           tlm.SIZE_BUCKETS)
        self._dispatch_hist = scope.histogram("dispatch_latency_s",
                                              tlm.TIME_BUCKETS_S)

    # ---------------- key-range routing ----------------
    def slot_of(self, worker_id: str) -> int:
        slot = self._home_cache.get(worker_id)
        if slot is None:
            slot = key_hash(worker_id) % self.n_slots
            self._home_cache[worker_id] = slot
        return slot

    def home_shard(self, worker_id: str) -> int:
        return self._range_owner[self.slot_of(worker_id)]

    def unit_slot(self, unit_id: int) -> int:
        return unit_id % self.n_slots

    # ---------------- membership (elastic) ----------------
    def join(self, worker_id: str) -> WorkerInfo:
        return self.shards[self.home_shard(worker_id)].join(worker_id)

    def leave(self, worker_id: str) -> None:
        # the worker may hold leases on foreign shards (stealing): drop
        # them everywhere it has state
        for s in self.shards:
            if worker_id in s.workers or worker_id in s._worker_leases:
                s.leave(worker_id)
        self._queues.pop(worker_id, None)

    # ---------------- unit lifecycle ----------------
    def submit(self, unit_id: int, payload: dict, *,
               replication: Optional[int] = None,
               quorum: Optional[int] = None) -> WorkUnit:
        prev = self._unit_shard.get(unit_id)
        sidx = self._range_owner[self.unit_slot(unit_id)]
        if prev is not None and prev != sidx:
            wu_prev = self.shards[prev].units.get(unit_id)
            if wu_prev is not None and not wu_prev.completed:
                # resubmit of a unit that migrated to a non-home shard:
                # keep it where it lives so the open entry is reused
                sidx = prev
        self._unit_shard[unit_id] = sidx
        return self.shards[sidx].submit(unit_id, payload,
                                        replication=replication,
                                        quorum=quorum)

    # ---------------- dispatch: watermark queue + stealing -------------
    def _valid_entry(self, worker_id: str, sidx: int, unit_id: int) -> bool:
        # a queued lease may have expired/migrated/completed since refill
        if self._unit_shard.get(unit_id) != sidx:
            return False
        wu = self.shards[sidx].units.get(unit_id)
        return (wu is not None and not wu.completed
                and worker_id in wu.leases)

    def _refill(self, worker_id: str, q: Deque[Tuple[int, int]],
                now: float) -> None:
        want = self.watermark + self.refill_batch - len(q)
        home = self.home_shard(worker_id)
        got = self.shards[home].request_batch(worker_id, want)
        if got:
            self.metrics.refills.inc()
            self.metrics.refill_units.inc(len(got))
            if self.tel.tracing:
                self.tel.event("refill", worker=worker_id, shard=home,
                               n=len(got))
            q.extend((home, wu.unit_id) for wu in got)
            return
        if not self.steal:
            return
        # home is dry: steal from the largest open backlog, at the tail
        victim, backlog = -1, 0
        for i, s in enumerate(self.shards):
            if i != home and self.shard_alive[i] and s.open_backlog() > backlog:
                victim, backlog = i, s.open_backlog()
        if victim < 0:
            return
        got = self.shards[victim].request_batch(worker_id, want, tail=True)
        if got:
            self.metrics.steals.inc()
            self.metrics.steal_units.inc(len(got))
            if self.tel.tracing:
                self.tel.event("steal", worker=worker_id, shard=victim,
                               n=len(got), home=home)
            q.extend((victim, wu.unit_id) for wu in got)

    def request_work(self, worker_id: str) -> Optional[WorkUnit]:
        """O(1) pop from the volunteer's watermark queue; batch refill
        (then steal) only when the queue runs low."""
        if not self.tel.tracing:
            return self._request_work(worker_id)
        t0 = time.perf_counter()
        wu = self._request_work(worker_id)
        self._dispatch_hist.observe(time.perf_counter() - t0)
        return wu

    def _request_work(self, worker_id: str) -> Optional[WorkUnit]:
        now = self.clock()
        home = self.shards[self.home_shard(worker_id)]
        info = home.join(worker_id)
        if now < info.backoff_until:
            home.metrics.rejected_requests.inc()
            return None
        q = self._queues.setdefault(worker_id, deque())
        if len(q) < self.watermark:
            self._refill(worker_id, q, now)
        while q:
            sidx, unit_id = q.popleft()
            if self._valid_entry(worker_id, sidx, unit_id):
                return self.shards[sidx].units[unit_id]
        # every refill source is dry: exponential back-off on the home
        # shard (only a successful dispatch resets it)
        home.backoff(worker_id, now)
        return None

    # ---------------- results: per-round batched quorum ----------------
    def report(self, worker_id: str, unit_id: int, result_hash: str) -> bool:
        """Buffer the result; quorum validates at the next round flush.

        -> True only when this call's flush completed the unit (callers
        needing completion should watch ``drain_completed``, as the
        trainer already does)."""
        self._report_buf.append((worker_id, unit_id, result_hash))
        if len(self._report_buf) >= self.report_batch_max:
            done = self.flush_reports()
            return any(uid == unit_id for uid, _ in done)
        return False

    def flush_reports(self) -> List[tuple[int, str]]:
        """Apply buffered results grouped by shard, one quorum check per
        touched unit per shard (``report_batch``)."""
        if not self._report_buf:
            return []
        buf, self._report_buf = self._report_buf, []
        by_shard: Dict[int, List[Tuple[str, int, str]]] = {}
        for worker_id, unit_id, h in buf:
            sidx = self._unit_shard.get(unit_id)
            if sidx is None:
                continue               # unknown unit: drop silently
            by_shard.setdefault(sidx, []).append((worker_id, unit_id, h))
        done: List[tuple[int, str]] = []
        for sidx, reports in by_shard.items():
            done.extend(self.shards[sidx].report_batch(reports))
        self.metrics.report_flushes.inc()
        self._flush_hist.observe(len(buf))
        return done

    # ---------------- progress ----------------
    def open_backlog(self) -> int:
        return sum(s.open_backlog() for s in self.shards)

    def done(self) -> bool:
        self.flush_reports()
        return self.open_backlog() == 0

    def pending(self) -> List[WorkUnit]:
        self.flush_reports()
        out: List[WorkUnit] = []
        for s in self.shards:
            out.extend(s.pending())
        return out

    def drain_completed(self) -> List[tuple[int, str]]:
        self.flush_reports()
        out = self._migrated_completed
        self._migrated_completed = []
        for s in self.shards:
            out.extend(s.drain_completed())
        return out

    def canonical_results(self) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for s in self.shards:
            out.update(s.canonical_results())
        return out

    def _expire_leases(self, now: float) -> None:
        for i, s in enumerate(self.shards):
            if self.shard_alive[i]:
                s._expire_leases(now)

    # ---------------- credit ----------------
    def credit_transfer(self, worker_id: str, moved_bytes: int,
                        dedup_bytes: int = 0) -> None:
        self.shards[self.home_shard(worker_id)].credit_transfer(
            worker_id, moved_bytes, dedup_bytes)

    @property
    def workers(self) -> Dict[str, WorkerInfo]:
        """Merged per-worker view (a worker that stole work has state on
        several shards); credit/counters sum, alive ORs."""
        merged: Dict[str, WorkerInfo] = {}
        for s in self.shards:
            for wid, info in s.workers.items():
                m = merged.get(wid)
                if m is None:
                    merged[wid] = m = WorkerInfo(wid, info.joined)
                    m.alive = False
                m.credit += info.credit
                m.completed += info.completed
                m.invalid += info.invalid
                m.uplink_bytes += info.uplink_bytes
                m.uplink_dedup += info.uplink_dedup
                m.alive = m.alive or info.alive
                m.backoff_until = max(m.backoff_until, info.backoff_until)
        return merged

    @property
    def stats(self) -> Dict[str, int]:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.stats.items():
                agg[k] = agg.get(k, 0) + v
        agg.update(self.plane_stats)
        agg["shards"] = self.n_shards
        agg["shards_alive"] = sum(self.shard_alive)
        return agg

    # ---------------- failover ----------------
    def alive_shards(self) -> List[int]:
        return [i for i, a in enumerate(self.shard_alive) if a]

    def fail_shard(self, index: int) -> Dict[str, int]:
        """Kill shard ``index``: deterministically reassign its key-range
        slots to the survivors and migrate its state.

        * open units move to the new owner of their range slot — results,
          lease history (``ever_leased``) and escalation counters travel,
          live leases drop (counted) and re-issue on the target;
        * completed units copy over so late reports and credit settling
          still see them; the un-drained completion log is preserved;
        * per-worker credit/counters merge into each worker's *new* home
          shard — total minted credit is conserved.

        -> migration summary dict."""
        if not self.shard_alive[index]:
            raise ValueError(f"shard {index} is already down")
        survivors = [i for i in self.alive_shards() if i != index]
        if not survivors:
            raise ValueError("cannot kill the last alive shard")
        # drain the report inbox first: buffered results must apply where
        # their workers are joined, or their credit share would vanish
        # when the unit completes on a shard that never saw the worker
        self.flush_reports()
        self.shard_alive[index] = False
        self.metrics.shard_kills.inc()
        tel = self.tel
        kseq = tel.event("kill_shard", shard=index) if tel.tracing else 0
        # deterministic slot reassignment: slot -> survivor round-robin
        for slot in range(self.n_slots):
            if self._range_owner[slot] == index:
                self._range_owner[slot] = survivors[slot % len(survivors)]
        dead = self.shards[index]
        # preserve completions that were not yet drained
        self._migrated_completed.extend(dead.drain_completed())
        moved_open = moved_done = dropped = 0
        for unit_id, wu in dead.units.items():
            target_idx = self._range_owner[self.unit_slot(unit_id)]
            target = self.shards[target_idx]
            self._unit_shard[unit_id] = target_idx
            if wu.completed:
                target.units[unit_id] = wu
                moved_done += 1
                continue
            dropped += len(wu.leases)
            dead.metrics.dropped_leases.inc(len(wu.leases))
            if tel.tracing:
                for wid in wu.leases:
                    tel.event("lease_drop", unit=unit_id, worker=wid,
                              shard=index, cause="shard_kill",
                              cause_seq=kseq)
            wu.leases.clear()          # heap/mirror entries go stale
            wu.straggler_issued = False
            target.units[unit_id] = wu
            target._open.append(unit_id)
            target._n_open += 1
            moved_open += 1
            if tel.tracing:
                tel.event("migrate", unit=unit_id, shard=target_idx,
                          from_shard=index)
            # every worker in the unit's lease history needs a ledger slot
            # on the target, or completion there would drop their credit
            # (a late report from a pre-kill lease holder is still valid)
            for wid in wu.ever_leased:
                if wid not in target.workers:
                    src = dead.workers.get(wid)
                    ghost = WorkerInfo(wid, src.joined if src else 0.0)
                    ghost.alive = src.alive if src else False
                    target.workers[wid] = ghost
        # merge volunteer accounting into each worker's new home shard
        for wid, info in dead.workers.items():
            home = self.shards[self.home_shard(wid)]
            m = home.workers.get(wid)
            if m is None or not m.alive:
                m = home.join(wid) if info.alive else \
                    home.workers.setdefault(wid, WorkerInfo(wid, info.joined))
                m.alive = info.alive
            m.credit += info.credit
            m.completed += info.completed
            m.invalid += info.invalid
            m.uplink_bytes += info.uplink_bytes
            m.uplink_dedup += info.uplink_dedup
            m.backoff_until = max(m.backoff_until, info.backoff_until)
            m.backoff_k = max(m.backoff_k, info.backoff_k)
        # retire the dead shard's state so aggregate stats don't double
        # count workers and the view classes skip it
        dead.units = {}
        dead._open.clear()
        dead._open_stale = 0
        dead._n_open = 0
        dead._lease_heap.clear()
        dead._worker_leases.clear()
        dead.workers = {}
        self.metrics.migrated_units.inc(moved_open)
        return {"reassigned_open": moved_open, "copied_completed": moved_done,
                "dropped_leases": dropped}

    def shard_report(self) -> List[Dict[str, int]]:
        """Per-shard load view (benchmarks / ops)."""
        return [{"shard": i, "alive": int(self.shard_alive[i]),
                 "open": s.open_backlog(), "workers": len(s.workers),
                 "dispatched": s.stats["dispatched"],
                 "completed": s.stats["completed"]}
                for i, s in enumerate(self.shards)]
