"""Replicated snapshot chains across volunteer hosts.

The paper's V-BOINC server is a single trusted node: every capsule fetch
and result upload flows through one ChunkStore, so one disk loss destroys
every snapshot chain.  Volunteer fleets have enormous *storage* capacity
(Anderson & Fedak), and PRs 1+4 already give us a verified, dedup-aware
object protocol in both directions (the ``Wire`` verbs: ``plan_send``/
``send`` down, ``plan_recv``/``recv`` up) — a ``ReplicaSet`` fans every
primary write out over exactly that machinery so any peer can take over.

Design:

* **Write path** — ``put``/``put_delta``/``put_buffer``/``ingest`` write
  to the primary and append the new ref to a *bounded outbox*; the
  snapshot hot path never blocks on a peer (enqueue is O(1), no peer I/O).
  ``pump`` drains the outbox off the hot path: each ref's chain closure is
  exported from the primary (``send``) and ``recv``-ed by every alive peer that
  lacks any of it, so every replica re-hashes every record and validates
  chain depths — a corrupt primary cannot poison its peers.  Delivery is
  pluggable (``transport``) so the churn simulator can drop, delay and
  reorder messages deterministically; messages are self-contained chain
  closures, so redelivery and reordering are safe (recv is idempotent).
* **Read repair** — when ``resolve``/``get`` on the primary hits a
  missing or torn object (integrity = re-hash on read), the chain is
  healed in place from the first peer that can serve it: the packed
  records travel through ``recv``, which re-verifies every hash and
  chain depth before anything lands.
* **Failover** — ``promote`` redesignates any alive member as primary;
  the set keeps presenting the ChunkStore interface, so a
  ``VBoincServer`` or ``SnapshotManager`` holding the set transparently
  serves ``fetch_capsule``/``report_result``/``restore`` from the
  promoted peer (``VBoincServer.failover`` wires this).
* **GC** — ``gc`` marks the closure of live refs across the *whole set*
  (a delta record held only by the primary still pins its parent on every
  peer), sweeps the primary inline and defers the peer sweeps to the next
  ``pump`` — a peer never drops a parent the primary still references,
  and gc adds no peer I/O to the snapshot hot path either.
* ``replication_factor`` reports how many alive members hold a ref;
  ``sync`` is the anti-entropy pass that brings a revived member back up
  to date.

The outbox is bounded: under sustained peer outage old entries are
dropped (counted in ``rstats``) rather than stalling the writer — ``sync``
repairs the gap once a peer returns, exactly BOINC's eventual-consistency
posture toward flaky volunteers.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional

from repro.core import telemetry as tlm
from repro.core.chunkstore import (DELTA_PREFIX, ChunkStore, _warn_wire,
                                   is_delta_ref)
from repro.core.membership import Membership

DEFAULT_OUTBOX_LIMIT = 4096

# transport(peer_index, records) -> delivered?  (None = deliver in-process)
Transport = Callable[[int, Dict[str, bytes]], bool]


class ReplicaSet(Membership):
    """N chunk stores presenting one ChunkStore-shaped interface.

    ``members[primary_index]`` serves reads and takes writes; every write
    is asynchronously fanned to the alive peers through the bounded
    outbox.  Unknown attributes delegate to the current primary, so
    ``SnapshotManager``/``VBoincServer``/``push_update`` code written
    against ``ChunkStore`` runs unchanged against a ``ReplicaSet``.
    Membership verbs (``mark_down``/``mark_up``/``remove``/``promote``)
    come from the shared :class:`Membership` mixin — the same interface
    ``ChurnSim`` drives the edge-cache tier through — with the
    replica-specific bookkeeping (parked refs, promotion metrics) in the
    ``_on_*`` hooks.
    """

    def __init__(self, primary: ChunkStore, peers: Iterable[ChunkStore] = (),
                 *, outbox_limit: int = DEFAULT_OUTBOX_LIMIT,
                 transport: Optional[Transport] = None,
                 telemetry: Optional[tlm.Telemetry] = None):
        self._init_membership([primary, *peers])
        self.outbox: deque[str] = deque()
        self.outbox_limit = int(outbox_limit)
        self.transport = transport
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop: Optional[threading.Event] = None
        self._gc_keep: Optional[set[str]] = None   # deferred peer sweep
        # refs owed only to down members, re-queued on mark_up — keeps a
        # long outage from re-scanning the same refs every pump
        self._parked: Dict[int, deque[str]] = {}
        # telemetry registry behind the historical rstats shape; the
        # namespace is `rmetrics` (not `metrics`) so `.metrics` still
        # delegates to the primary ChunkStore via __getattr__
        self.tel = tlm.resolve(telemetry)
        scope = self.tel.scope("replica")
        self.rmetrics = scope.counters(
            "enqueued", "sent", "send_failed", "deferred",
            "outbox_dropped", "missing_at_pump", "repaired",
            "repair_failed", "promotions", "synced")
        self.rstats = scope.view()
        self._pump_hist = scope.histogram("pump_batch", tlm.SIZE_BUCKETS)

    # -- membership --------------------------------------------------------
    @property
    def primary(self) -> ChunkStore:
        return self.members[self.primary_index]

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self.primary, name)

    def alive_peers(self) -> List[tuple[int, ChunkStore]]:
        return [(i, m) for i, m in enumerate(self.members)
                if i != self.primary_index and i not in self._down]

    # Membership hooks: the verbs themselves live on the shared mixin
    def _on_down(self, index: int) -> None:
        if self.tel.tracing:
            self.tel.event("member_down", member=index)

    def _on_up(self, index: int) -> None:
        """Refs parked for the member during its outage re-enter the
        outbox and ship on the next pump."""
        if self.tel.tracing:
            self.tel.event("member_up", member=index)
        with self._lock:
            for ref in self._parked.pop(index, ()):
                self.outbox.append(ref)
                if len(self.outbox) > self.outbox_limit:
                    self.outbox.popleft()
                    self.rmetrics.outbox_dropped.inc()

    def _on_remove(self, index: int) -> None:
        """Pumps stop deferring refs for a member that will never
        return (``index`` is its pre-removal slot)."""
        self._parked = {i - (i > index): q
                        for i, q in self._parked.items() if i != index}

    def _on_promote(self, index: int) -> None:
        self.rmetrics.promotions.inc()
        if self.tel.tracing:
            self.tel.event("promote", member=index)

    def promote_best(self) -> int:
        """Promote the alive member holding the most objects (deterministic
        tie-break: lowest index).  Returns the promoted index."""
        best, best_n = None, -1
        for i, m in enumerate(self.members):
            if i in self._down:
                continue
            n = sum(1 for _ in m.all_refs())
            if n > best_n:
                best, best_n = i, n
        if best is None:
            raise IOError("no alive member to promote")
        self.promote(best)
        return best

    def replication_factor(self, ref: str) -> int:
        """How many alive members hold ``ref``."""
        return sum(1 for i, m in enumerate(self.members)
                   if i not in self._down and m.has(ref))

    def replication_report(self, refs: Optional[Iterable[str]] = None) -> dict:
        """Factor summary over ``refs`` (default: the primary's objects)."""
        rs = list(refs) if refs is not None else list(self.primary.all_refs())
        target = len(self.members) - len(self._down)
        factors = [self.replication_factor(r) for r in rs]
        return {"objects": len(rs), "target": target,
                "min_factor": min(factors, default=target),
                "fully_replicated": sum(1 for f in factors if f >= target),
                "outbox": len(self.outbox),
                "parked": sum(len(q) for q in self._parked.values())}

    # -- hot write path: primary write + O(1) enqueue, no peer I/O ---------
    def _enqueue(self, ref: str) -> None:
        with self._lock:
            self.rmetrics.enqueued.inc()
            self.outbox.append(ref)
            if len(self.outbox) > self.outbox_limit:
                self.outbox.popleft()
                self.rmetrics.outbox_dropped.inc()

    def _park(self, index: int, ref: str) -> None:
        """Hold a ref owed to a down member (bounded, deduped, counted).
        Runs under the lock: ``mark_up``/``gc`` rebuild these queues, and
        the background pump must not append to an orphaned deque."""
        with self._lock:
            q = self._parked.setdefault(index, deque())
            if ref in q:
                return                   # a send-retry loop re-offers refs
            q.append(ref)
            self.rmetrics.deferred.inc()
            if len(q) > self.outbox_limit:
                q.popleft()
                self.rmetrics.outbox_dropped.inc()

    def put(self, data: bytes) -> str:
        h = self.primary.put(data)
        self._enqueue(h)
        return h

    def put_buffer(self, buf) -> list[str]:
        refs = self.primary.put_buffer(buf)
        for r in refs:
            self._enqueue(r)
        return refs

    def put_delta(self, parent_ref: str, xor_bytes: bytes, *,
                  full_bytes: Optional[bytes] = None) -> str:
        ref = self.primary.put_delta(parent_ref, xor_bytes,
                                     full_bytes=full_bytes)
        self._enqueue(ref)
        return ref

    def recv(self, records: Dict[str, bytes], *,
             client_id: Optional[str] = None) -> int:
        """Uplink writes replicate too: validated records land on the
        primary and their refs join the outbox."""
        written = self.primary.recv(records, client_id=client_id)
        for r in records:
            self._enqueue(r)
        return written

    def ingest(self, records: Dict[str, bytes], *,
               client_id: Optional[str] = None) -> int:
        """Deprecated: use ``recv``.  (Defined here, not delegated: the
        primary's shim would skip the replication enqueue.)"""
        _warn_wire("ReplicaSet.ingest", "recv")
        return self.recv(records, client_id=client_id)

    # -- read path with read-repair ----------------------------------------
    def get(self, ref: str) -> bytes:
        try:
            return self.primary.get(ref)
        except (OSError, KeyError):
            self.read_repair(ref)
            return self.primary.get(ref)

    def resolve(self, ref: str) -> bytes:
        try:
            return self.primary.resolve(ref)
        except (OSError, KeyError):
            self.read_repair(ref)
            return self.primary.resolve(ref)

    def get_buffer(self, refs: list[str]) -> bytes:
        return b"".join(self.get(r) for r in refs)

    def resolve_buffer(self, refs: list[str]) -> bytes:
        return b"".join(self.resolve(r) for r in refs)

    @staticmethod
    def _intact(store: ChunkStore, ref: str) -> bool:
        """Does ``store`` hold a hash-verified copy of ``ref``?"""
        try:
            if is_delta_ref(ref):
                store._delta_bytes(ref[len(DELTA_PREFIX):])
            else:
                store.get(ref)
            return True
        except (OSError, KeyError):
            return False

    def read_repair(self, ref: str) -> int:
        """Heal ``ref``'s chain on the primary from the first peer that can
        serve it.  Records re-enter through ``ingest``, so every healed
        object is re-hashed and its chain depth re-validated — a lying
        replica cannot poison the primary.  Returns objects healed."""
        if self.primary_index in self._down:
            raise IOError("primary is marked down; promote a replica first")
        for i, peer in self.alive_peers():
            try:
                closure = peer.live_closure([ref])
            except (OSError, KeyError):
                continue                     # peer lacks part of the chain
            bad = sorted(r for r in closure
                         if not self._intact(self.primary, r))
            try:
                records = peer.send(bad)
            except (OSError, KeyError):
                continue                     # peer torn too; try the next
            for r in bad:                    # drop torn copies first so the
                if self.primary.has(r):      # ingest dedup re-writes them
                    self.primary.delete(r)
            try:
                self.primary.recv(records)
            except (OSError, KeyError):
                continue
            self.rmetrics.repaired.inc(len(bad))
            if self.tel.tracing:
                self.tel.event("repair", ref=ref[:16], healed=len(bad),
                               peer=i)
            for r in bad:                    # healed objects may be missing
                self._enqueue(r)             # on other peers too
            return len(bad)
        self.rmetrics.repair_failed.inc()
        raise IOError(f"read-repair: no alive replica can heal {ref[:14]}")

    # -- replication pump (off the hot path) -------------------------------
    def _deliver(self, peer_index: int, records: Dict[str, bytes]) -> bool:
        if self.transport is not None:
            try:
                return bool(self.transport(peer_index, records))
            except Exception:
                return False
        return self.deliver_direct(peer_index, records)

    def deliver_direct(self, peer_index: int,
                       records: Dict[str, bytes]) -> bool:
        """Apply one replication message to a member (the in-process wire).
        Used directly by transports that queue messages for later/reordered
        delivery.  Any sweep deferred by an earlier ``gc`` is applied
        first — a stale keep set must never revert this delivery."""
        if peer_index in self._down:
            return False
        self._apply_deferred_gc()
        try:
            self.members[peer_index].recv(records)
        except (OSError, KeyError):
            return False
        return True

    def pump(self, max_msgs: Optional[int] = None) -> int:
        """Drain (a slice of) the outbox: fan each ref's chain closure to
        every peer that lacks any of it.  Returns messages sent.

        Failed sends re-queue the ref for the next pump.  A member marked
        *down* never silently drains the outbox: the ref is *parked* for
        it (``rstats["deferred"]``, bounded like the outbox) and re-queued
        by ``mark_up`` — so a long outage neither loses accounting nor
        re-scans the same refs every pump; ``remove`` forgets a member
        that will never return, and ``sync`` repairs any bounded drops on
        revival.  A ref the primary no longer holds is counted in
        ``rstats["missing_at_pump"]`` (benign when GC collected it first;
        after a failover it flags objects committed on the dead primary
        that never fanned out).  Each ref's closure is exported from the
        primary once and subset per peer.  Any peer sweep deferred by
        ``gc`` is applied first, so a ref delivered this cycle cannot be
        swept by an older live view."""
        self._apply_deferred_gc()
        with self._lock:
            batch = list(self.outbox)
            self.outbox.clear()
        n = len(batch) if max_msgs is None else min(len(batch), max_msgs)
        if n:
            self._pump_hist.observe(n)
        sent, retry = 0, []
        for ref in batch[:n]:
            # closure + export run under the primary's gc lock: a background
            # SnapshotWriter's trailing gc (its own thread) must not sweep a
            # chain between "has(ref)" and "export_records" — exports are
            # all-or-nothing per ref, deliveries happen outside the lock
            with self.primary.gc_lock:
                if not self.primary.has(ref):
                    self.rmetrics.missing_at_pump.inc()
                    continue
                try:
                    closure = self.primary.live_closure([ref])
                except (OSError, KeyError):
                    retry.append(ref)        # torn locally; read-repair may
                    continue                 # restore it before next pump
                failed = False
                targets: List[tuple[int, List[str]]] = []
                union: set[str] = set()
                for i in range(len(self.members)):
                    if i == self.primary_index:
                        continue
                    if i in self._down:
                        self._park(i, ref)   # owed; re-queued on mark_up
                        continue
                    needed = sorted(r for r in closure
                                    if not self.members[i].has(r))
                    if needed:
                        targets.append((i, needed))
                        union.update(needed)
                records = {}
                if union:
                    try:
                        records = self.primary.send(sorted(union))
                    except (OSError, KeyError):
                        retry.append(ref)
                        continue
            if records:
                for i, needed in targets:
                    if self._deliver(i, {r: records[r] for r in needed}):
                        self.rmetrics.sent.inc()
                        sent += 1
                    else:
                        self.rmetrics.send_failed.inc()
                        failed = True
            if failed:
                retry.append(ref)
        with self._lock:
            self.outbox.extendleft(reversed(batch[n:]))
            self.outbox.extend(retry)
            while len(self.outbox) > self.outbox_limit:
                self.outbox.popleft()
                self.rmetrics.outbox_dropped.inc()
        if n and self.tel.tracing:
            self.tel.event("pump", refs=n, sent=sent)
        return sent

    def flush(self, max_rounds: int = 64) -> int:
        """Pump until the outbox drains or stops making progress."""
        total = 0
        for _ in range(max_rounds):
            before = len(self.outbox)
            if not before:
                break
            total += self.pump()
            if len(self.outbox) >= before:
                break                        # every send failing; give up
        return total

    def sync(self, refs: Optional[Iterable[str]] = None) -> int:
        """Anti-entropy: replicate the closure of ``refs`` (default: every
        primary object) to every alive peer.  Brings a revived member back
        up to date and repairs outbox-overflow gaps.  Each missing object
        is read and hash-verified from the primary once, however many
        peers need it."""
        self._apply_deferred_gc()        # a stale sweep must not undo this
        base = list(refs) if refs is not None else \
            sorted(self.primary.all_refs())
        try:
            closure = self.primary.live_closure(base)
        except (OSError, KeyError):
            closure = set(base)
        needed_by_peer: List[tuple[int, List[str]]] = []
        union: set[str] = set()
        for i, peer in self.alive_peers():
            needed = [r for r in sorted(closure) if not peer.has(r)]
            if needed:
                needed_by_peer.append((i, needed))
                union.update(needed)
        records: Dict[str, bytes] = {}
        for r in sorted(union):
            try:
                records.update(self.primary.send([r]))
            except (OSError, KeyError):
                continue                     # torn locally; skip
        moved = 0
        for i, needed in needed_by_peer:
            msg = {r: records[r] for r in needed if r in records}
            if msg and self._deliver(i, msg):
                moved += len(msg)
        self.rmetrics.synced.inc(moved)
        return moved

    # -- GC: global closure mark, per-member sweep -------------------------
    def _parent_any(self, ref: str) -> Optional[str]:
        """A delta's parent ref, read from whichever member holds the
        record (primary first)."""
        order = [self.primary_index] + [i for i, _ in self.alive_peers()]
        for i in order:
            m = self.members[i]
            try:
                if m.has(ref):
                    return m._get_delta(ref).parent
            except (OSError, KeyError):
                continue
        return None

    def live_closure_all(self, refs: Iterable[str]) -> set[str]:
        """Closure over delta parents using records from *any* member — a
        chain half-replicated across the set still pins its parents
        everywhere."""
        keep: set[str] = set()
        stack = list(refs)
        while stack:
            r = stack.pop()
            if r in keep:
                continue
            keep.add(r)
            if is_delta_ref(r):
                p = self._parent_any(r)
                if p is not None:
                    stack.append(p)
        return keep

    def gc(self, live: set[str]) -> int:
        """Mark the *global* closure of ``live`` — a peer never drops a
        parent the primary still references (and vice versa) — then sweep
        the primary inline and defer the peer sweeps to the next ``pump``,
        keeping peer I/O off the snapshot hot path (``SnapshotManager``
        auto-gc calls this synchronously after every snapshot).  Returns
        objects removed from the primary, to match ``ChunkStore.gc``.

        Mark + primary sweep hold the primary's ``gc_lock`` (reentrant, so
        a SnapshotManager guard around this call nests fine): an async
        writer mid-commit holds the same lock, so this sweep can never see
        its objects before their manifest registers."""
        with self.primary.gc_lock:
            keep = self.live_closure_all(live)
            with self._lock:                 # dead refs need no replication
                self.outbox = deque(r for r in self.outbox if r in keep)
                self._parked = {i: deque(r for r in q if r in keep)
                                for i, q in self._parked.items()}
            dead = [r for r in self.primary.all_refs() if r not in keep]
            for r in dead:
                self.primary.delete(r)
            self.primary.sweep_tmp()
            self._gc_keep = keep             # newest live view wins
        return len(dead)

    def _apply_deferred_gc(self) -> None:
        """Sweep alive peers against the live view recorded by the last
        ``gc``.  Runs at the top of ``pump``, before any delivery, so an
        object replicated this cycle can never be swept by an older keep
        set.  A member down at sweep time keeps its garbage until the next
        gc after its revival (or a ``sync``)."""
        with self._lock:
            keep, self._gc_keep = self._gc_keep, None
        if keep is None:
            return
        for _, peer in self.alive_peers():
            for r in [r for r in peer.all_refs() if r not in keep]:
                peer.delete(r)
            peer.sweep_tmp()

    # -- optional background pump ------------------------------------------
    def start(self, interval_s: float = 0.05) -> None:
        """Drain the outbox from a daemon thread (production mode; tests
        drive ``pump`` explicitly for determinism)."""
        if self._thread is not None:
            return
        self._stop = threading.Event()

        def loop():
            while not self._stop.wait(interval_s):
                self.pump()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="replica-pump")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        self.flush()
