"""Elastic volunteer training: the full V-BOINC loop on real jax compute.

One logical training job runs across an *unreliable* simulated volunteer
fleet: the scheduler leases micro-batch work units (replication + quorum),
workers execute the real jitted gradient function, the trainer combines
validated gradient contributions, applies the optimizer, and the
SnapshotManager takes periodic differencing snapshots.  Worker kills,
corrupt results and mid-run crash/restore are all exercised; determinism of
the data pipeline + gradient computation makes recovery bit-exact.

On a real fleet each worker is a pod running the same capsule; here they are
in-process actors — the protocol (leases, quorum hashes, back-off, recovery)
is identical.
"""
from __future__ import annotations

import hashlib
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core import telemetry as tlm
from repro.core.control import CapsuleRuntime, Coordinator, HostSupervisor
from repro.core.scheduler import SimClock, VolunteerScheduler
from repro.core.snapshots import SnapshotManager
from repro.core.uplink import DEFAULT_UPLINK_CHUNK, UplinkEncoder
from repro.data.pipeline import Cursor, DataConfig, TokenStream


def grad_hash(tree) -> str:
    h = hashlib.blake2b()
    for leaf in jax.tree.leaves(tree):
        h.update(memoryview(np.ascontiguousarray(np.asarray(leaf))).cast("B"))
    return h.hexdigest()


@dataclass
class SimWorker:
    """A volunteer host: speed, failure and corruption behaviour."""
    worker_id: str
    fail_prob: float = 0.0        # dies while holding a lease
    corrupt_prob: float = 0.0     # returns a wrong result (caught by quorum)
    rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0))
    alive: bool = True
    supervisor: Optional[HostSupervisor] = None


@dataclass
class RoundStats:
    """Per-round snapshot, derived from telemetry-registry deltas: every
    field below is ``after - before`` of a registry counter (scheduler,
    replica or trainer scope) bracketing the round — no hand-threaded
    per-round accumulators."""
    step: int
    loss: float
    units: int
    reissued: int
    duplicates: int
    invalid: int
    snapshot_bytes: int = 0
    snapshot_stall_ms: float = 0.0   # trainer-visible snapshot time only
    replicated: int = 0          # replication messages pumped this round
    # sharded scheduler plane accounting (0 on a single scheduler)
    steals: int = 0              # work-steal batches this round
    refills: int = 0             # watermark refill batches this round
    # delta-aware uplink accounting (0 unless uplink mode is on)
    uplink_dense: int = 0        # int8 payload had volunteers sent it whole
    uplink_moved: int = 0        # deduped bytes actually transferred up
    uplink_dedup: int = 0        # bytes the server already held
    lease_expiries: int = 0      # deadline-driven lease losses this round
    read_repairs: int = 0        # objects healed from peers this round


class VolunteerTrainer:
    """Synchronous-round volunteer data parallelism with full fault handling."""

    def __init__(self, *, grad_fn: Callable, apply_fn: Callable,
                 state, stream: TokenStream, micro_batches: int,
                 scheduler: Optional[VolunteerScheduler] = None,
                 snapshots: Optional[SnapshotManager] = None,
                 snapshot_every: int = 0, seed: int = 0,
                 compress_grads: bool = False,
                 server=None, project: Optional[str] = None,
                 uplink: bool = False,
                 uplink_chunk_bytes: int = DEFAULT_UPLINK_CHUNK,
                 uplink_mode: str = "auto",
                 replicas=None, edge=None,
                 telemetry: Optional[tlm.Telemetry] = None):
        """grad_fn(params, batch)->(loss, grads); apply_fn(state, grads)->state.

        ``scheduler`` may be a single ``VolunteerScheduler`` or a
        ``ShardedScheduler`` plane (``core/shardplane.py``) — the trainer
        drives both through the same request/report/drain interface; with
        a plane, each loop sweep is one quorum-validation batch and
        ``RoundStats.steals``/``refills`` report cross-shard traffic.

        ``compress_grads``: int8 + error-feedback compression of the combined
        gradient before the optimizer — the volunteer-uplink analogue of the
        cross-pod trick in optim/grad_compress.py (4x fewer bytes a volunteer
        would upload; the residual is carried on the coordinator).

        ``uplink``: the delta-aware upload path.  Each worker quantizes its
        unit gradient to int8 (stateless, so replicas agree bitwise), diffs
        the quantized image against its own previous round with the
        probe-then-gather kernel, and reports delta refs through
        ``server.report_result`` — only objects the server lacks move, and
        workers are credited by the deduped bytes they actually
        transferred.  Requires ``server`` (a VBoincServer) + ``project``
        (published there); the project's scheduler is used so quorum
        validation and uplink folding share one unit table.

        ``replicas``: a ``ReplicaSet`` whose primary backs the snapshot
        store.  Snapshot/uplink writes only *enqueue* on the hot path; the
        trainer pumps the outbox once per round, after the optimizer step
        and snapshot complete, so peer I/O never blocks a round.

        ``edge``: an ``EdgeTier`` fronting the snapshot store.
        ``restore_latest`` routes its download through edge discovery, so
        a re-attach wave drains from the caches instead of the primary
        (``last_restore_plan['route']`` records who served it)."""
        self.grad_fn = grad_fn
        self.apply_fn = apply_fn
        self.compress_grads = compress_grads
        self._compress_err = None
        self.state = state
        self.stream = stream
        self.micro_batches = micro_batches
        self.server = server
        self.project = project
        self.uplink = uplink
        self.uplink_chunk_bytes = uplink_chunk_bytes
        self.uplink_mode = uplink_mode
        if uplink and (server is None or project is None):
            raise ValueError("uplink mode needs server= and project=")
        if server is not None and project is not None:
            proj_sched = server.projects[project].scheduler
            if scheduler is None:
                scheduler = proj_sched
            elif scheduler is not proj_sched:
                raise ValueError("trainer scheduler must be the project's "
                                 "scheduler when a server is attached")
        self.sched = scheduler or VolunteerScheduler(clock=SimClock())
        self.replicas = replicas
        self.edge = edge
        self.snapshots = snapshots
        self.snapshot_every = snapshot_every
        self.cursor = Cursor()
        self.coordinator = Coordinator()
        self.workers: Dict[str, SimWorker] = {}
        self._rng = np.random.default_rng(seed)
        self._grad_cache: Dict[str, tuple] = {}   # result_hash -> (loss, grads)
        self._completed: Dict[int, str] = {}      # drained, not yet consumed
        self._uplink_enc: Dict[str, UplinkEncoder] = {}   # per volunteer
        # uplink accounting lives in the registry; RoundStats reads deltas
        self.tel = tlm.resolve(telemetry)
        scope = self.tel.scope("trainer")
        self.tmetrics = scope.counters("uplink_dense", "uplink_moved",
                                       "uplink_dedup", "folds")
        self.tstats = scope.view()
        # unit -> {worker: (moved, dedup)} awaiting quorum validation
        self._pending_credit: Dict[int, Dict[str, tuple]] = {}
        self.last_restore_plan: Optional[dict] = None
        self.history: List[RoundStats] = []
        # elastic membership: called when the fleet empties — a real
        # volunteer project keeps receiving new volunteers
        self.respawn: Optional[Callable[["VolunteerTrainer"], None]] = None
        # fault-injection hook (ChurnSim): called after every dispatch
        # sweep inside round(), while reports are still buffered and
        # leases may be open — the window where a mid-round shard kill
        # or worker loss is observable
        self.on_sweep: Optional[Callable[["VolunteerTrainer", int],
                                         None]] = None

    # ---------------- fleet management ----------------
    def add_worker(self, worker: SimWorker) -> None:
        runtime = CapsuleRuntime(worker.worker_id)
        sup = HostSupervisor(worker.worker_id, runtime)
        sup.control_vm("startvm")
        worker.supervisor = sup
        self.coordinator.register(sup)
        self.workers[worker.worker_id] = worker
        self.sched.join(worker.worker_id)

    def kill_worker(self, worker_id: str) -> None:
        w = self.workers.get(worker_id)
        if w is not None:
            w.alive = False
            w.supervisor.control_vm("poweroff")
            self.sched.leave(worker_id)

    # ---------------- one unit on one worker ----------------
    def _execute_unit(self, worker: SimWorker, unit) -> None:
        batch = self.stream.batch(unit.payload["batch_index"])
        sub = {k: v for k, v in batch.items()}
        loss, grads = self.grad_fn(self.state.params, sub)
        if self.uplink:
            self._execute_unit_uplink(worker, unit, float(loss), grads)
            return
        h = grad_hash(grads)
        if worker.rng.random() < worker.corrupt_prob:
            h = "corrupt-" + h[:16]        # wrong result; quorum rejects
        else:
            self._grad_cache[h] = (float(loss), grads)
        self.sched.report(worker.worker_id, unit.unit_id, h)

    def _execute_unit_uplink(self, worker: SimWorker, unit,
                             loss: float, grads) -> None:
        """Report a unit as a quantized delta stream, not a bare hash.

        Quantization is stateless per unit (no error feedback on the
        worker) so replicated units agree bitwise and quorum validation
        still works; the canonical gradient is the dequantized image the
        server can itself reconstruct from the ingested refs."""
        from repro.optim import grad_compress
        wid = worker.worker_id
        comp, _ = grad_compress.compress(grads, grad_compress.init_error(grads))
        grads = grad_compress.decompress(comp, grads)
        h = grad_hash(grads)
        if worker.rng.random() < worker.corrupt_prob:
            h = "corrupt-" + h[:16]        # wrong result; quorum rejects
        else:
            self._grad_cache[h] = (loss, grads)
        enc = self._uplink_enc.setdefault(wid, UplinkEncoder(
            chunk_bytes=self.uplink_chunk_bytes, mode=self.uplink_mode))
        update = enc.encode(comp)
        store = self.server.store
        log0 = dict(store.uplinks.get(wid, {}))
        self.server.report_result(self.project, wid, unit.unit_id, h,
                                  update=update)
        log1 = store.uplinks.get(wid, {})
        enc.gc()        # the client store only needs the latest round
        moved = log1.get("bytes_in", 0) - log0.get("bytes_in", 0)
        dedup = log1.get("bytes_dedup", 0) - log0.get("bytes_dedup", 0)
        self.tmetrics.uplink_dense.inc(update.dense_bytes)
        self.tmetrics.uplink_moved.inc(moved)
        self.tmetrics.uplink_dedup.inc(dedup)
        if moved or dedup:
            # credit settles only after quorum validates this worker's
            # result (_settle_uplink_credit) — an always-invalid worker
            # must not farm transfer credit by pushing valid-looking bytes
            self._pending_credit.setdefault(unit.unit_id, {})[wid] = (
                moved, dedup)

    def _settle_uplink_credit(self, drained) -> None:
        """Grant deferred transfer credit for quorum-validated units:
        only workers whose result matched the canonical hash earn by the
        deduped bytes they moved."""
        for uid, _h in drained:
            unit = self.sched.units.get(uid)
            for wid, (mv, dd) in self._pending_credit.pop(uid, {}).items():
                if unit is not None \
                        and unit.results.get(wid) == unit.canonical:
                    self.sched.credit_transfer(wid, mv, dd)

    # ---------------- one synchronous round ----------------
    def _stat_snapshot(self) -> Dict[str, dict]:
        """Registry counters RoundStats derives its per-round deltas from:
        scheduler (or plane aggregate), replica set, trainer scope."""
        snap = {"sched": dict(self.sched.stats),
                "trainer": dict(self.tstats)}
        if self.replicas is not None:
            snap["replica"] = dict(self.replicas.rstats)
        return snap

    @staticmethod
    def _delta(before: Dict[str, dict], after: Dict[str, dict],
               group: str, key: str) -> int:
        return (after.get(group, {}).get(key, 0)
                - before.get(group, {}).get(key, 0))

    def round(self, step: int) -> RoundStats:
        base_index = self.cursor.next_index
        for k in range(self.micro_batches):
            self.sched.submit(step * self.micro_batches + k,
                              {"batch_index": base_index + k, "step": step})
        self.cursor.next_index += self.micro_batches

        before = self._stat_snapshot()
        guard = 0
        while not self.sched.done():
            guard += 1
            if guard > 100_000:
                raise RuntimeError("scheduler did not converge")
            progressed = False
            for w in list(self.workers.values()):
                if not w.alive or not w.supervisor.runtime.accepting_work:
                    continue
                unit = self.sched.request_work(w.worker_id)
                if unit is None:
                    continue
                progressed = True
                if self._rng.random() < w.fail_prob:
                    self.kill_worker(w.worker_id)   # dies holding the lease
                    continue
                self._execute_unit(w, unit)
            if self.on_sweep is not None:
                self.on_sweep(self, step)
            if not progressed:
                # everyone is backing off or leases are pending: advance the
                # simulated clock past back-off windows and lease deadlines
                if isinstance(self.sched.clock, SimClock):
                    self.sched.clock.advance(
                        max(self.sched.backoff_max_s, self.sched.deadline_s)
                        + 1.0)
                else:
                    self.sched._expire_leases(self.sched.clock() + 1e9)
                if not any(w.alive for w in self.workers.values()):
                    if self.respawn is not None:
                        self.respawn(self)
                    if not any(w.alive for w in self.workers.values()):
                        raise RuntimeError("all volunteers died")

        # combine validated canonical results — incremental view: drain
        # only the units that completed since last round instead of
        # scanning every unit ever submitted (canonical_results())
        drained = self.sched.drain_completed()
        self._settle_uplink_credit(drained)
        self._completed.update(drained)
        round_units = sorted(uid for uid in self._completed
                             if uid // self.micro_batches == step)
        losses, grads = [], None
        for uid in round_units:
            loss, g = self._grad_cache[self._completed.pop(uid)]
            self.tmetrics.folds.inc()
            if self.tel.tracing:
                self.tel.event("fold", unit=uid, round=step)
            losses.append(loss)
            grads = g if grads is None else jax.tree.map(
                lambda a, b: a + b, grads, g)
        grads = jax.tree.map(lambda g: g / self.micro_batches, grads)
        if self.compress_grads:
            from repro.optim import grad_compress
            if self._compress_err is None:
                self._compress_err = grad_compress.init_error(grads)
            comp, self._compress_err = grad_compress.compress(
                grads, self._compress_err)
            grads = grad_compress.decompress(comp, grads)
        self.state = self.apply_fn(self.state, grads)
        self._grad_cache.clear()

        snapshot_stall_ms, snapshot_bytes = 0.0, 0
        if (self.snapshots is not None and self.snapshot_every
                and (step + 1) % self.snapshot_every == 0):
            import time as _time
            t0 = _time.perf_counter()
            # async managers: plan synchronously, persist in the background
            # — the round pays only the device probe (+ any backpressure)
            res = self.snapshots.snapshot(
                self.state, step=step,
                aux={"cursor": self.cursor.to_state(), "round": step},
                block=not getattr(self.snapshots, "is_async", False))
            snapshot_stall_ms = (_time.perf_counter() - t0) * 1e3
            info = res if not isinstance(res, Future) \
                else self.snapshots.last_info
            if info is not None:
                snapshot_bytes = info.new_bytes
        if self.replicas is not None:
            # fan this round's writes to the peers off the hot path
            self.replicas.pump()

        # the per-round snapshot is pure registry deltas bracketing the
        # round — pump/read-repair/uplink all count through one mechanism
        after = self._stat_snapshot()
        d = self._delta
        stats = RoundStats(
            step=step, loss=float(np.mean(losses)),
            units=self.micro_batches,
            reissued=d(before, after, "sched", "reissued"),
            duplicates=d(before, after, "sched", "duplicates"),
            invalid=d(before, after, "sched", "invalid_results"),
            steals=d(before, after, "sched", "steals"),
            refills=d(before, after, "sched", "refills"),
            lease_expiries=d(before, after, "sched", "lease_expiries"),
            replicated=d(before, after, "replica", "sent"),
            read_repairs=d(before, after, "replica", "repaired"),
            uplink_dense=d(before, after, "trainer", "uplink_dense"),
            uplink_moved=d(before, after, "trainer", "uplink_moved"),
            uplink_dedup=d(before, after, "trainer", "uplink_dedup"),
            snapshot_stall_ms=snapshot_stall_ms,
            snapshot_bytes=snapshot_bytes,
        )
        self.history.append(stats)
        return stats

    def run(self, steps: int, start_step: int = 0) -> List[RoundStats]:
        return [self.round(s) for s in range(start_step, start_step + steps)]

    def dump_flight_recorder(self, path) -> int:
        """Write the telemetry hub's event ring to ``path`` as JSONL.

        Returns the number of events written (0 when tracing is off)."""
        return self.tel.dump_jsonl(path)

    # ---------------- crash recovery ----------------
    def restore_latest(self, abstract_state, *,
                       client_hashes: Optional[set] = None) -> int:
        """Restore state+cursor from the latest snapshot; returns next step.

        ``client_hashes``: refs this volunteer already holds (e.g. from a
        previous attach).  When given, ``last_restore_plan`` records the
        block-level download accounting — the same ``plan_send`` (Wire)
        the server's ``fetch_capsule`` uses, so a re-attaching volunteer
        downloads only the delta objects written since it detached.  With
        an ``edge`` tier attached the download routes through discovery
        and ``last_restore_plan['route']`` names the serving member."""
        if client_hashes is not None:
            if self.edge is not None:
                self.snapshots.wait()
                sid = self.snapshots.latest()
                if sid is None:
                    raise ValueError("no snapshots available")
                refs = self.snapshots.get_manifest(sid).all_refs()
                res = self.edge.fetch(refs, client_hashes)
                missing, moved, dedup = (res.missing, res.bytes_moved,
                                         res.bytes_dedup)
                route = res.route
            else:
                missing, moved, dedup = self.snapshots.download_plan(
                    client_hashes)
                route = "origin"
            self.last_restore_plan = {"missing": len(missing),
                                      "bytes_moved": moved,
                                      "bytes_dedup": dedup,
                                      "route": route}
        state, aux = self.snapshots.restore(target_tree=abstract_state)
        self.state = state
        self.cursor = Cursor.from_state(aux["cursor"])
        return int(aux["round"]) + 1
