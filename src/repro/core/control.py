"""Two-level control plane (paper §III-D, Fig. 2).

V-BOINC's host client controls BOTH the VM process (``controlvm``) and the
BOINC client *inside* the VM (``boinccmd`` wrapped through ``guestcontrol``).
The analogue: a Coordinator ("V-BOINC server") talks to per-pod
HostSupervisors ("host client"), each of which forwards wrapped command
envelopes to its CapsuleRuntime ("inner client").  Commands that target the
runtime itself (suspend/resume of the *capsule*) are distinct from commands
that target the workload inside it (suspend/resume of the *job*) — exactly
the paper's ``controlvm`` vs ``guestcontrol`` split.

All state machines are real; transport is in-process (RPC on a cluster).
Heartbeat timeouts replace the paper's VM-process watching for failure
detection, feeding the scheduler's re-issue path.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class RuntimeState(enum.Enum):
    CREATED = "created"
    BOOTING = "booting"          # compile/restore in progress
    RUNNING = "running"
    SUSPENDED = "suspended"
    HALTED = "halted"
    FAILED = "failed"


class JobState(enum.Enum):
    IDLE = "idle"
    RUNNING = "running"
    SUSPENDED = "suspended"
    NO_MORE_WORK = "no_more_work"


# boinccmd verbs (paper §III-D) + controlvm verbs
GUEST_COMMANDS = {"suspend", "resume", "reset", "detach", "update",
                  "nomorework", "allowmorework"}
VM_COMMANDS = {"startvm", "poweroff", "pause", "unpause", "snapshot"}


@dataclass
class Envelope:
    """A wrapped command, as the middleware wraps boinccmd in guestcontrol."""
    target: str                  # "vm" | "guest"
    verb: str
    args: dict = field(default_factory=dict)
    issued: float = field(default_factory=time.time)


class CapsuleRuntime:
    """The 'inner BOINC client': runs jobs inside the capsule."""

    def __init__(self, name: str, *, on_snapshot: Optional[Callable] = None):
        self.name = name
        self.state = RuntimeState.CREATED
        self.job_state = JobState.IDLE
        self.on_snapshot = on_snapshot
        self.log: List[str] = []
        self.last_heartbeat = time.time()
        self.completed_units: List[Any] = []

    def _note(self, msg: str) -> None:
        self.log.append(msg)

    def boot(self) -> None:
        assert self.state in (RuntimeState.CREATED, RuntimeState.HALTED)
        self.state = RuntimeState.BOOTING
        self.state = RuntimeState.RUNNING
        self.job_state = JobState.RUNNING
        self._note("booted")

    def heartbeat(self) -> None:
        self.last_heartbeat = time.time()

    def handle(self, env: Envelope) -> dict:
        self.heartbeat()
        if env.target == "vm":
            return self._handle_vm(env)
        return self._handle_guest(env)

    def _handle_vm(self, env: Envelope) -> dict:
        if env.verb == "startvm":
            self.boot()
        elif env.verb == "poweroff":
            self.state = RuntimeState.HALTED
            self.job_state = JobState.IDLE
        elif env.verb == "pause":
            if self.state is RuntimeState.RUNNING:
                self.state = RuntimeState.SUSPENDED
        elif env.verb == "unpause":
            if self.state is RuntimeState.SUSPENDED:
                self.state = RuntimeState.RUNNING
        elif env.verb == "snapshot":
            if self.on_snapshot is not None:
                info = self.on_snapshot()
                self._note(f"snapshot {getattr(info, 'snapshot_id', '?')}")
                return {"ok": True, "snapshot": info}
        else:
            return {"ok": False, "error": f"unknown vm verb {env.verb}"}
        self._note(f"vm:{env.verb} -> {self.state.value}")
        return {"ok": True, "state": self.state.value}

    def _handle_guest(self, env: Envelope) -> dict:
        if self.state is not RuntimeState.RUNNING:
            # guestcontrol needs a live VM (paper: commands are executed
            # on the virtual machine via Guest Additions)
            return {"ok": False, "error": "capsule not running"}
        if env.verb == "suspend":
            self.job_state = JobState.SUSPENDED
        elif env.verb == "resume":
            self.job_state = JobState.RUNNING
        elif env.verb == "nomorework":
            self.job_state = JobState.NO_MORE_WORK
        elif env.verb == "allowmorework":
            self.job_state = JobState.RUNNING
        elif env.verb == "reset":
            self.job_state = JobState.IDLE
            self.completed_units.clear()
        elif env.verb in ("detach", "update"):
            pass  # project-attachment bookkeeping
        else:
            return {"ok": False, "error": f"unknown guest verb {env.verb}"}
        self._note(f"guest:{env.verb} -> {self.job_state.value}")
        return {"ok": True, "job_state": self.job_state.value}

    @property
    def accepting_work(self) -> bool:
        return (self.state is RuntimeState.RUNNING
                and self.job_state is JobState.RUNNING)


class HostSupervisor:
    """The 'host BOINC client': owns one capsule runtime, wraps commands."""

    def __init__(self, host_id: str, runtime: CapsuleRuntime,
                 heartbeat_timeout: float = 5.0):
        self.host_id = host_id
        self.runtime = runtime
        self.heartbeat_timeout = heartbeat_timeout

    def control_vm(self, verb: str, **args) -> dict:
        if verb not in VM_COMMANDS:
            return {"ok": False, "error": f"not a vm verb: {verb}"}
        return self.runtime.handle(Envelope("vm", verb, args))

    def boinccmd(self, verb: str, **args) -> dict:
        """Wrap a boinccmd in a guestcontrol envelope (paper Fig. 2)."""
        if verb not in GUEST_COMMANDS:
            return {"ok": False, "error": f"not a boinccmd verb: {verb}"}
        return self.runtime.handle(Envelope("guest", verb, args))

    def healthy(self, now: Optional[float] = None) -> bool:
        now = now if now is not None else time.time()
        if self.runtime.state is RuntimeState.FAILED:
            return False
        return (now - self.runtime.last_heartbeat) < self.heartbeat_timeout

    def status(self) -> dict:
        return {"host": self.host_id,
                "vm": self.runtime.state.value,
                "job": self.runtime.job_state.value,
                "healthy": self.healthy()}


class Coordinator:
    """The 'V-BOINC server' view of the fleet: registry + failure detection."""

    def __init__(self):
        self.hosts: Dict[str, HostSupervisor] = {}

    def register(self, sup: HostSupervisor) -> None:
        self.hosts[sup.host_id] = sup

    def deregister(self, host_id: str) -> None:
        self.hosts.pop(host_id, None)

    def broadcast(self, target: str, verb: str, **args) -> dict:
        out = {}
        for hid, sup in self.hosts.items():
            fn = sup.control_vm if target == "vm" else sup.boinccmd
            out[hid] = fn(verb, **args)
        return out

    def failed_hosts(self, now: Optional[float] = None) -> list[str]:
        return [hid for hid, sup in self.hosts.items()
                if not sup.healthy(now)]

    def fleet_status(self) -> list[dict]:
        return [sup.status() for sup in self.hosts.values()]
