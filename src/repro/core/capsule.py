"""Compute Capsules — the "VM image" (paper §III-B).

A capsule is a hermetic, topology-free bundle: arch config + shape + run
config + a content-addressed manifest.  "Compile your application on a single
architecture" becomes *define once, instantiate on any volunteer mesh*:
``instantiate(mesh)`` resolves shardings and compiles the step functions for
that mesh, measuring boot time (the paper's <20 s VM boot requirement maps to
compile+restore latency, reported by the Fig-3 benchmark).

The manifest hash gives volunteers end-to-end integrity over what they run
(the paper's trusted-application concern), and the V-BOINC *server*
(core/server.py) distributes capsules exactly like VM images.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_arch
from repro.core.chunkstore import sha256
from repro.models.lm import RunConfig


@dataclass(frozen=True)
class CapsuleSpec:
    arch_name: str
    shape_name: str
    run: RunConfig
    version: str = "1"
    # reduced override for CPU smoke capsules (None = full assigned config)
    arch_override: Optional[ArchConfig] = None

    def manifest(self) -> dict:
        run = dataclasses.asdict(self.run)
        run["compute_dtype"] = jnp.dtype(self.run.compute_dtype).name
        m = {"arch": self.arch_name, "shape": self.shape_name,
             "run": run, "version": self.version}
        if self.arch_override is not None:
            m["arch_override"] = dataclasses.asdict(self.arch_override)
        return m

    @property
    def manifest_hash(self) -> str:
        return sha256(json.dumps(self.manifest(), sort_keys=True,
                                 default=str).encode())

    @property
    def arch(self) -> ArchConfig:
        return self.arch_override or get_arch(self.arch_name)

    @property
    def shape(self) -> ShapeConfig:
        return SHAPES[self.shape_name]


@dataclass
class BootedCapsule:
    spec: CapsuleSpec
    cell: Any                      # launch.cell.Cell (jitted step + specs)
    boot_wall_s: float             # "VM boot time"
    mesh_desc: str

    @property
    def step(self):
        return self.cell.step


def boot(spec: CapsuleSpec, mesh, *, verify_hash: Optional[str] = None,
         compile_now: bool = True) -> BootedCapsule:
    """Instantiate a capsule on a mesh (any topology).

    ``verify_hash`` rejects a tampered capsule before any compute runs —
    the volunteer-side trust check.
    """
    from repro.launch.cell import build_cell   # local import: no jax at module load

    if verify_hash is not None and verify_hash != spec.manifest_hash:
        raise PermissionError("capsule manifest hash mismatch — refusing to "
                              "boot untrusted image")
    t0 = time.time()
    cell = build_cell(spec.arch, spec.shape, mesh, spec.run)
    if compile_now:
        cell.step.lower(*cell.abstract_args).compile()
    desc = "x".join(str(s) for s in mesh.devices.shape) \
        + ":" + ",".join(mesh.axis_names)
    return BootedCapsule(spec, cell, time.time() - t0, desc)
