"""Unified telemetry plane: metrics registry, lifecycle tracer, flight recorder.

Operating a volunteer fleet (paper §IV-C; Anderson 2018's monitoring
subsection) is impossible without per-unit visibility: when a unit
reissues at step 40k under churn, the operator must be able to answer
*which* shard kill, lease expiry or replica wipe caused it — from the
trace alone, deterministically.  This module is that substrate, shared
by every layer built since PR 1:

* a **metrics registry** — typed counters, gauges and fixed-bucket
  histograms that the scheduler, shard plane, replica set, chunk store,
  snapshot writer, serving engine and trainer register against.  Each
  component keeps its historical ``.stats`` dict *shape* as a read-only
  live :class:`StatsView`, so every existing test, benchmark and launch
  summary reads the same keys it always did;
* a **work-unit lifecycle tracer** — structured span events
  (``submit → dispatch/lease → report → quorum → fold``, reissue events
  with an explicit cause, store events ``put/ingest/pump/repair`` and
  control events ``kill_shard/promote/failover``) carrying unit id,
  worker key, shard id and a timestamp from the component's own clock
  (the tests' ``SimClock``), so a fixed seed yields a byte-identical
  event stream;
* a bounded **flight recorder** — events land in a ring buffer
  (``deque(maxlen=capacity)``) that ``ChurnSim`` and the trainer dump to
  JSONL on fault or on demand;
* :func:`trace_reduce` — the post-mortem tool: reconstructs per-unit
  causal chains from a dump and flags anomalies (unclosed spans, quorum
  without a lease, reissue storms, reissues with no recorded cause).

The hub is process-wide by default (module-level instance, so components
constructed without an explicit ``telemetry=`` all share it) but fully
injectable: tests build isolated ``Telemetry(...)`` instances per run
and pass them down, which is what makes the two-runs-same-seed
byte-identity assertion possible in one process.

Tracing is off by default.  The disabled path is one attribute check in
``event()`` (and hot paths guard with ``if tel.tracing`` before building
kwargs), cheap enough that the committed ``BENCH_scheduler.json``
flat-ratio gate holds with telemetry compiled in —
``benchmarks/telemetry_overhead.py`` measures exactly this and
``check_regression.py --kind telemetry`` gates it.

Reading a flight-recorder dump: one lost unit, end to end
---------------------------------------------------------

Say a churn run reports one reissue you did not expect.  The trainer (or
``ChurnSim`` with ``dump_on_fault=``) wrote ``events.jsonl``; grep the
unit::

    $ grep '"unit": 17' events.jsonl
    {"kind": "submit", "quorum": 1, "replication": 1, "seq": 402,
     "shard": 1, "t": 84.0, "unit": 17}
    {"kind": "dispatch", "dup": false, "seq": 431, "shard": 1,
     "t": 84.0, "unit": 17, "worker": "v3"}
    {"kind": "lease", "deadline": 144.0, "seq": 432, "shard": 1,
     "t": 84.0, "unit": 17, "worker": "v3"}
    {"cause": "shard_kill", "cause_seq": 440, "kind": "lease_drop",
     "seq": 445, "shard": 1, "t": 91.0, "unit": 17, "worker": "v3"}
    {"kind": "dispatch", "dup": false, "seq": 471, "shard": 2,
     "t": 91.0, "unit": 17, "worker": "v5"}
    ...
    {"kind": "quorum", "canonical": "9f2c...", "results": 1,
     "seq": 505, "shard": 2, "t": 91.0, "unit": 17}
    {"kind": "fold", "seq": 530, "t": 91.0, "unit": 17}

The story reads straight off the chain: unit 17 was submitted to shard
1, leased to worker ``v3``, and the lease was dropped — not by a worker
death or a deadline, but by ``cause: shard_kill`` pointing (via
``cause_seq: 440``) at the exact fault event::

    $ grep '"seq": 440' events.jsonl
    {"kind": "kill_shard", "seq": 440, "shard": 1, "t": 91.0}

After the kill the unit migrated (a ``migrate`` event with
``from_shard: 1``), re-dispatched on shard 2, met quorum and was folded
into the round — a closed ``submit → … → fold`` span.  Running
``python -m repro.core.telemetry events.jsonl`` does this for every
unit at once: it prints chain/anomaly counts and would have flagged the
unit as ``unattributed_reissue`` had the ``cause`` field been missing,
or ``unclosed_span`` had it never reached quorum.
"""
from __future__ import annotations

import json
import time
from bisect import bisect_left
from collections import deque
from collections.abc import Mapping
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricScope", "StatsView",
    "Telemetry", "TraceReport", "get_default", "set_default", "resolve",
    "trace_reduce", "TIME_BUCKETS_S", "SIZE_BUCKETS",
]

# latency buckets (seconds): 1us .. 1s, the dispatch/probe range
TIME_BUCKETS_S = (1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 5e-4,
                  1e-3, 1e-2, 1e-1, 1.0)
# count/size buckets: pump batch sizes, report flush sizes
SIZE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096)

DEFAULT_CAPACITY = 1 << 16


class Counter:
    """Monotonic-by-convention accumulator.  ``inc`` accepts negative
    deltas for the rare reconciliation path (e.g. the uplink dedup
    clawback when ingest validation rejects a batch) — the registry
    records what happened; policy lives in the caller."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0):
        self.name = name
        self.value = value

    def inc(self, n=1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Point-in-time value (queue depth, alive shards)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0):
        self.name = name
        self.value = value

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Fixed-bucket histogram (upper-bound semantics, like Prometheus
    ``le``): ``counts[i]`` tallies observations ``<= buckets[i]``, the
    final slot is +Inf.  Buckets are fixed at registration so exposition
    never allocates."""

    __slots__ = ("name", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, buckets: Tuple[float, ...]):
        self.name = name
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def __repr__(self) -> str:
        return f"Histogram({self.name}, n={self.count})"


class StatsView(Mapping):
    """Read-only live dict view over a scope's scalar metrics.

    Preserves the historical ``component.stats["key"]`` read shape —
    ``dict(view)``, ``.items()``, ``.get()`` and ``in`` all work — while
    rejecting the old write shape: mutation must go through the typed
    metric objects (``component.metrics.key.inc()``), which is what the
    ``tools/lint_stats_mutations.py`` CI step enforces at the AST level.
    """

    __slots__ = ("_metrics",)

    def __init__(self, metrics: Dict[str, object]):
        self._metrics = metrics

    def __getitem__(self, key: str):
        return self._metrics[key].value

    def __iter__(self):
        return iter(self._metrics)

    def __len__(self) -> int:
        return len(self._metrics)

    def __setitem__(self, key, value):      # pragma: no cover - guard
        raise TypeError("stats is a read-only telemetry view; "
                        "use <component>.metrics.<key>.inc()")

    def __delitem__(self, key):             # pragma: no cover - guard
        raise TypeError("stats is a read-only telemetry view")

    def __repr__(self) -> str:
        return repr({k: m.value for k, m in self._metrics.items()})


class MetricScope:
    """One component's corner of the registry (``scheduler``,
    ``replica``, ...).  Scopes are cheap; every component instance gets
    its own, labeled with a hub-assigned instance index so Prometheus
    exposition can tell shards apart."""

    __slots__ = ("hub", "name", "index", "_scalars", "_histograms")

    def __init__(self, hub: "Telemetry", name: str, index: int):
        self.hub = hub
        self.name = name
        self.index = index
        self._scalars: Dict[str, object] = {}    # insertion-ordered
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, key: str, value=0) -> Counter:
        c = self._scalars.get(key)
        if c is None:
            c = self._scalars[key] = Counter(key, value)
        return c

    def counters(self, *keys: str) -> SimpleNamespace:
        """Register ``keys`` in order; -> namespace of Counter objects
        (the component's ``metrics`` handle — attribute access beats a
        dict lookup on the hot path)."""
        return SimpleNamespace(**{k: self.counter(k) for k in keys})

    def gauge(self, key: str, value=0) -> Gauge:
        g = self._scalars.get(key)
        if g is None:
            g = self._scalars[key] = Gauge(key, value)
        return g

    def histogram(self, key: str,
                  buckets: Tuple[float, ...] = TIME_BUCKETS_S) -> Histogram:
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(key, buckets)
        return h

    def view(self) -> StatsView:
        """Live read-only mapping over the scalars registered so far
        *and later* — the backward-compatible ``.stats`` face."""
        return StatsView(self._scalars)


class Telemetry:
    """The hub: scope factory, event recorder, exporters.

    ``clock`` is any zero-arg callable returning a float timestamp —
    pass the component graph's shared ``SimClock`` for deterministic
    traces (the default, wall time, is for live runs where byte
    identity does not matter).  ``tracing`` gates the recorder; metrics
    always count (they are the ``.stats`` backing store)."""

    def __init__(self, *, clock=None, tracing: bool = False,
                 capacity: int = DEFAULT_CAPACITY):
        self.clock = clock if clock is not None else time.time
        self.tracing = bool(tracing)
        self.capacity = int(capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self._scopes: List[MetricScope] = []
        self._scope_counts: Dict[str, int] = {}

    # ---------------- registry ----------------
    def scope(self, name: str) -> MetricScope:
        index = self._scope_counts.get(name, 0)
        self._scope_counts[name] = index + 1
        sc = MetricScope(self, name, index)
        self._scopes.append(sc)
        return sc

    # ---------------- recorder ----------------
    def event(self, kind: str, *, unit=None, worker=None, shard=None,
              **extra) -> int:
        """Record one structured event; -> its seq (0 when disabled).

        The seq is the causal handle: fault emitters capture it and
        stamp dependent events with ``cause=``/``cause_seq=`` at the
        source, so ``trace_reduce`` attributes reissues by reading the
        trace, never by inference."""
        if not self.tracing:
            return 0
        self._seq += 1
        ev = {"seq": self._seq, "t": self.clock(), "kind": kind}
        if unit is not None:
            ev["unit"] = unit
        if worker is not None:
            ev["worker"] = worker
        if shard is not None:
            ev["shard"] = shard
        if extra:
            ev.update(extra)
        self.events.append(ev)
        return self._seq

    def reset_events(self) -> None:
        self.events.clear()

    # ---------------- exporters ----------------
    def event_lines(self) -> List[str]:
        """Deterministic JSONL lines for the ring's current contents
        (sorted keys, fixed separators — byte-stable given a
        deterministic clock)."""
        return [json.dumps(ev, sort_keys=True, separators=(",", ":"))
                for ev in self.events]

    def dump_jsonl(self, path) -> int:
        """Write the flight recorder to ``path``; -> events written."""
        lines = self.event_lines()
        with open(path, "w") as f:
            for line in lines:
                f.write(line + "\n")
        return len(lines)

    def prometheus(self) -> str:
        """Prometheus text exposition of every registered metric.
        Metric families are ``repro_<scope>_<key>`` with an
        ``instance`` label distinguishing multiple scopes of one name
        (e.g. per-shard schedulers)."""
        out: List[str] = []
        seen_type: set = set()
        for sc in self._scopes:
            label = f'{{scope="{sc.name}",instance="{sc.index}"}}'
            for key, m in sc._scalars.items():
                fam = f"repro_{sc.name}_{key}"
                if fam not in seen_type:
                    kind = "gauge" if isinstance(m, Gauge) else "counter"
                    out.append(f"# TYPE {fam} {kind}")
                    seen_type.add(fam)
                out.append(f"{fam}{label} {m.value}")
            for key, h in sc._histograms.items():
                fam = f"repro_{sc.name}_{key}"
                if fam not in seen_type:
                    out.append(f"# TYPE {fam} histogram")
                    seen_type.add(fam)
                cum = 0
                for le, c in zip(h.buckets, h.counts):
                    cum += c
                    out.append(f'{fam}_bucket{{scope="{sc.name}",'
                               f'instance="{sc.index}",le="{le}"}} {cum}')
                out.append(f'{fam}_bucket{{scope="{sc.name}",'
                           f'instance="{sc.index}",le="+Inf"}} {h.count}')
                out.append(f"{fam}_sum{label} {h.sum}")
                out.append(f"{fam}_count{label} {h.count}")
        return "\n".join(out) + "\n"


# ---------------- process-wide default hub ----------------
_DEFAULT = Telemetry()


def get_default() -> Telemetry:
    return _DEFAULT


def set_default(tel: Telemetry) -> Telemetry:
    """Install ``tel`` as the process default (launchers call this once
    before constructing the component graph); -> the previous default."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tel
    return prev


def resolve(tel: Optional[Telemetry]) -> Telemetry:
    """Component constructors: explicit hub wins, else the default."""
    return tel if tel is not None else _DEFAULT


# ---------------- trace_reduce: post-mortem causal chains ----------------

# kinds that re-queue a unit and therefore demand a recorded cause
REISSUE_KINDS = frozenset({"reissue", "lease_drop"})
# kinds a cause_seq may legitimately point at
FAULT_KINDS = frozenset({"kill_shard", "worker_leave", "lease_expire",
                         "member_down", "wipe", "failover"})


@dataclass
class UnitChain:
    """Everything the trace says about one unit, in seq order."""
    unit: object
    submits: List[int] = field(default_factory=list)
    dispatches: List[Tuple[int, Optional[str]]] = field(default_factory=list)
    reports: List[Tuple[int, Optional[str]]] = field(default_factory=list)
    quorums: List[int] = field(default_factory=list)
    folds: List[int] = field(default_factory=list)
    reissues: List[dict] = field(default_factory=list)

    def closed(self, require_fold: bool = False) -> bool:
        ok = bool(self.submits and self.dispatches and self.reports
                  and self.quorums)
        if require_fold:
            ok = ok and bool(self.folds)
        return ok

    def stage(self) -> str:
        """Furthest lifecycle stage this unit reached."""
        for name in ("folds", "quorums", "reports", "dispatches", "submits"):
            if getattr(self, name):
                return name[:-1] if name != "dispatches" else "dispatch"
        return "none"


@dataclass
class TraceReport:
    units: Dict[object, UnitChain]
    anomalies: List[dict]
    reissues: int = 0
    attributed: int = 0
    completed: int = 0
    folded: int = 0
    events: int = 0

    @property
    def attribution_rate(self) -> float:
        return 1.0 if self.reissues == 0 else self.attributed / self.reissues

    def anomaly_kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for a in self.anomalies:
            out[a["kind"]] = out.get(a["kind"], 0) + 1
        return out

    def summary(self) -> str:
        ak = self.anomaly_kinds()
        parts = [f"events={self.events}", f"units={len(self.units)}",
                 f"completed={self.completed}", f"folded={self.folded}",
                 f"reissues={self.reissues}",
                 f"attributed={self.attributed} "
                 f"({self.attribution_rate:.0%})",
                 f"anomalies={sum(ak.values())}"]
        if ak:
            parts.append("[" + ", ".join(f"{k}={v}"
                                         for k, v in sorted(ak.items()))
                         + "]")
        return "  ".join(parts)


def _iter_events(events) -> Iterable[dict]:
    if isinstance(events, Telemetry):
        return list(events.events)
    return events


def trace_reduce(events, *, storm_threshold: int = 5,
                 require_fold: bool = False) -> TraceReport:
    """Reconstruct per-unit causal chains from an event stream and flag
    anomalies.  ``events``: a ``Telemetry`` hub, an iterable of event
    dicts, or parsed JSONL lines.

    Anomalies flagged (each a dict with ``kind``, ``unit``, detail):

    * ``unclosed_span`` — a submitted unit that never reached quorum
      (or never folded, with ``require_fold=True``);
    * ``quorum_without_lease`` — quorum recorded for a unit with no
      dispatch event (forged or lost provenance);
    * ``report_without_lease`` — a worker reported a unit it was never
      dispatched (by this trace);
    * ``unattributed_reissue`` — a reissue/lease_drop with no recorded
      ``cause``, or a ``cause_seq`` pointing at a non-fault event;
    * ``reissue_storm`` — one unit reissued ``>= storm_threshold``
      times.
    """
    evs = _iter_events(events)
    by_seq: Dict[int, dict] = {}
    units: Dict[object, UnitChain] = {}
    anomalies: List[dict] = []
    reissues = attributed = completed = folded = n = 0
    any_fold = False

    def chain(uid) -> UnitChain:
        ch = units.get(uid)
        if ch is None:
            ch = units[uid] = UnitChain(uid)
        return ch

    for ev in evs:
        n += 1
        seq = ev.get("seq")
        if seq is not None:
            by_seq[seq] = ev
        kind = ev.get("kind")
        uid = ev.get("unit")
        if kind == "submit" and uid is not None:
            chain(uid).submits.append(seq)
        elif kind == "dispatch" and uid is not None:
            chain(uid).dispatches.append((seq, ev.get("worker")))
        elif kind == "report" and uid is not None:
            chain(uid).reports.append((seq, ev.get("worker")))
        elif kind == "quorum" and uid is not None:
            chain(uid).quorums.append(seq)
            completed += 1
        elif kind == "fold" and uid is not None:
            chain(uid).folds.append(seq)
            folded += 1
            any_fold = True
        elif kind in REISSUE_KINDS and uid is not None:
            chain(uid).reissues.append(ev)
            reissues += 1
            cause = ev.get("cause")
            cseq = ev.get("cause_seq")
            cause_ev = by_seq.get(cseq) if cseq else None
            ok = cause is not None and (
                cseq in (None, 0)
                or (cause_ev is not None
                    and cause_ev.get("kind") in FAULT_KINDS))
            if ok:
                attributed += 1
            else:
                anomalies.append({"kind": "unattributed_reissue",
                                  "unit": uid, "seq": seq,
                                  "cause": cause, "cause_seq": cseq})

    require_fold = require_fold or any_fold
    for uid, ch in units.items():
        if ch.quorums and not ch.dispatches:
            anomalies.append({"kind": "quorum_without_lease", "unit": uid,
                              "seq": ch.quorums[0]})
        if ch.submits and not ch.closed(require_fold=require_fold):
            anomalies.append({"kind": "unclosed_span", "unit": uid,
                              "stage": ch.stage()})
        leased_workers = {w for _, w in ch.dispatches}
        for seq, w in ch.reports:
            if w is not None and w not in leased_workers:
                anomalies.append({"kind": "report_without_lease",
                                  "unit": uid, "worker": w, "seq": seq})
        if len(ch.reissues) >= storm_threshold:
            anomalies.append({"kind": "reissue_storm", "unit": uid,
                              "count": len(ch.reissues)})

    return TraceReport(units=units, anomalies=anomalies, reissues=reissues,
                       attributed=attributed, completed=completed,
                       folded=folded, events=n)


def load_jsonl(path) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def main(argv=None) -> int:
    """CLI: ``python -m repro.core.telemetry dump.jsonl`` — print the
    post-mortem summary and every anomaly."""
    import argparse
    ap = argparse.ArgumentParser(
        description="trace_reduce: per-unit causal chains from a "
                    "flight-recorder JSONL dump")
    ap.add_argument("dump", help="JSONL event dump (Telemetry.dump_jsonl)")
    ap.add_argument("--storm-threshold", type=int, default=5)
    ap.add_argument("--unit", default=None,
                    help="print the raw chain for one unit id")
    args = ap.parse_args(argv)
    events = load_jsonl(args.dump)
    rep = trace_reduce(events, storm_threshold=args.storm_threshold)
    print(rep.summary())
    if args.unit is not None:
        uid = int(args.unit)
        for ev in events:
            if ev.get("unit") == uid or ev.get("seq") in {
                    r.get("cause_seq") for r in
                    rep.units.get(uid, UnitChain(uid)).reissues}:
                print(" ", json.dumps(ev, sort_keys=True))
    for a in rep.anomalies:
        print(f"ANOMALY {a}")
    return 1 if rep.anomalies else 0


if __name__ == "__main__":
    raise SystemExit(main())
