"""Volunteer work-unit scheduler (paper §II/§IV-C semantics).

BOINC's server distributes work units to untrusted, unreliable volunteers.
Production mechanics implemented here:

* leases with deadlines — a unit not reported by its deadline is re-issued;
* replication factor R + **quorum validation**: a unit is only accepted when
  ``quorum`` identical results arrive (results are hashes of deterministic
  computation, so agreement is bitwise — BOINC's validator);
* **exponential back-off**: a client whose request is rejected (server busy /
  no work) must wait 2^k * base seconds, protecting the server from request
  storms (paper §IV-C);
* **straggler mitigation**: when a unit's lease is mostly elapsed and spare
  capacity exists, a duplicate is dispatched and the first valid result wins
  — at most one duplicate per lease lifetime, so a slow unit cannot fan out
  to every requesting volunteer;
* **unsolicited-result rejection**: a result from a worker that never held a
  lease on the unit is dropped (``stats["unsolicited_results"]``) — a
  free-riding client cannot poison quorum with forged reports;
* elastic membership: workers join/leave at any time; deterministic work
  units (data/pipeline.py) mean any replacement volunteer reproduces the
  exact result.

The scheduler is pure bookkeeping (no jax): the elastic trainer drives it
with real train-step executions.  Three structures keep every hot operation
O(1) amortized regardless of how many units have ever been submitted, which
is what lets ``core/shardplane.py`` hold a million open units per shard:

* a pending deque that sheds completed units lazily (head fast-path, full
  rebuild only when more than half the entries are stale);
* a **deadline min-heap** of (expiry, unit, worker) lease entries, so
  expiry pops only the leases that are actually due instead of scanning
  every open unit per request (entries invalidated by a report/leave are
  skipped lazily);
* a per-worker lease index, so ``leave`` drops a volunteer's leases in
  O(its leases), not O(open units).

``tasks_per_day_capacity`` feeds the paper's 8.8 M-tasks/day
server-throughput comparison; ``benchmarks/server_throughput.py`` measures
the dispatch latency curve this buys.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.core import telemetry as tlm


class SimClock:
    """Deterministic clock for simulation/tests (advanced by the driver)."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@dataclass
class WorkUnit:
    unit_id: int
    payload: dict                      # e.g. {"batch_index": i, "step": s}
    replication: int = 1
    quorum: int = 1
    deadline_s: float = 60.0
    max_extra_results: int = 4         # replica escalation cap (BOINC's
                                       # max_error_results analogue)
    # runtime bookkeeping
    results: Dict[str, str] = field(default_factory=dict)   # worker -> hash
    leases: Dict[str, float] = field(default_factory=dict)  # worker -> t0
    ever_leased: Set[str] = field(default_factory=set)      # lease history
    completed: bool = False
    canonical: Optional[str] = None    # winning result hash
    reissues: int = 0
    straggler_issued: bool = False     # duplicate sent this lease lifetime

    def quorum_met(self) -> bool:
        counts: Dict[str, int] = {}
        for h in self.results.values():
            counts[h] = counts.get(h, 0) + 1
        for h, c in counts.items():
            if c >= self.quorum:
                self.canonical = h
                return True
        return False


@dataclass
class WorkerInfo:
    worker_id: str
    joined: float
    backoff_until: float = 0.0
    backoff_k: int = 0
    credit: float = 0.0          # beyond-paper: the credit system V-BOINC defers
    completed: int = 0
    invalid: int = 0
    uplink_bytes: int = 0        # deduped bytes this worker actually moved
    uplink_dedup: int = 0        # bytes the server already held for it
    alive: bool = True


class VolunteerScheduler:
    def __init__(self, *, replication: int = 1, quorum: int = 1,
                 deadline_s: float = 60.0, backoff_base_s: float = 0.5,
                 backoff_max_s: float = 60.0, straggler_factor: float = 0.8,
                 max_extra_results: int = 4, clock=time.time,
                 telemetry: Optional[tlm.Telemetry] = None,
                 shard_id: Optional[int] = None):
        assert quorum <= replication
        self.replication = replication
        self.quorum = quorum
        self.max_extra_results = max_extra_results
        self.deadline_s = deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.units: Dict[int, WorkUnit] = {}
        # assignable/pending index: completed units leave this deque lazily —
        # the head is cleared on every dispatch, mid-deque stale entries are
        # swept only when they outnumber live ones (amortized O(1) per
        # completion instead of a full rebuild each time)
        self._open: deque[int] = deque()
        self._open_stale = 0           # completed units still in _open
        self._n_open = 0               # exact open count (done() is O(1))
        # deadline min-heap: (expiry, unit_id, worker, lease_t0); entries
        # whose lease was already reported/dropped are skipped on pop
        self._lease_heap: List[Tuple[float, int, str, float]] = []
        # worker -> {unit_id: lease_t0}: mirrors WorkUnit.leases so leave()
        # drops exactly this worker's leases without touching other units
        self._worker_leases: Dict[str, Dict[int, float]] = {}
        # incremental completion view: (unit_id, canonical hash) appended
        # as quorums are met, drained by the trainer each round — the
        # uplink analogue of the pending index (no O(all units) scans)
        self._completed_log: List[tuple[int, str]] = []
        self.workers: Dict[str, WorkerInfo] = {}
        # telemetry: typed counters behind the historical dict shape —
        # .stats stays a (read-only) mapping with the same keys, writes
        # go through .metrics so the registry is the single source
        self.tel = tlm.resolve(telemetry)
        self.shard_id = shard_id
        scope = self.tel.scope("scheduler")
        self.metrics = scope.counters(
            "dispatched", "completed", "reissued", "duplicates",
            "rejected_requests", "invalid_results", "dropped_leases",
            "unsolicited_results", "quorum_batches", "lease_expiries")
        self.stats = scope.view()
        self._dispatch_hist = scope.histogram("dispatch_latency_s",
                                              tlm.TIME_BUCKETS_S)

    # ---------------- membership (elastic) ----------------
    def join(self, worker_id: str) -> WorkerInfo:
        info = self.workers.get(worker_id)
        if info is None:
            info = WorkerInfo(worker_id, self.clock())
            self.workers[worker_id] = info
        elif not info.alive:
            # revive in place: a volunteer that left and came back keeps
            # its credit/completed/invalid/uplink ledger (replacing the
            # record wiped the counters, so every leave→rejoin cycle —
            # and the shard-failover merge that joins a worker on its new
            # home — destroyed minted credit)
            info.alive = True
            info.joined = self.clock()
            info.backoff_until = 0.0
            info.backoff_k = 0
        return info

    def leave(self, worker_id: str) -> None:
        info = self.workers.get(worker_id)
        if info is not None:
            info.alive = False
        tel = self.tel
        lseq = tel.event("worker_leave", worker=worker_id,
                         shard=self.shard_id) if tel.tracing else 0
        # drop leases so units re-issue immediately — O(this worker's
        # leases) via the per-worker index, not O(open units)
        for uid, t0 in self._worker_leases.pop(worker_id, {}).items():
            wu = self.units.get(uid)
            if (wu is not None and not wu.completed
                    and wu.leases.get(worker_id) == t0):
                del wu.leases[worker_id]
                wu.straggler_issued = False   # lease lifetime ended
                self.metrics.dropped_leases.inc()
                if tel.tracing:
                    tel.event("lease_drop", unit=uid, worker=worker_id,
                              shard=self.shard_id, cause="worker_leave",
                              cause_seq=lseq)

    # ---------------- unit lifecycle ----------------
    def submit(self, unit_id: int, payload: dict, *,
               replication: Optional[int] = None,
               quorum: Optional[int] = None) -> WorkUnit:
        # explicit values are honored even when falsy — only None falls
        # back to the scheduler default (a submit(quorum=0) used to be
        # silently replaced by the default, masking the misconfiguration)
        rep = self.replication if replication is None else replication
        quo = self.quorum if quorum is None else quorum
        if rep < 1 or quo < 1:
            raise ValueError(f"replication/quorum must be >= 1 "
                             f"(got replication={rep}, quorum={quo})")
        if quo > rep:
            raise ValueError(f"quorum {quo} > replication {rep}")
        wu = WorkUnit(unit_id, payload, replication=rep, quorum=quo,
                      deadline_s=self.deadline_s,
                      max_extra_results=self.max_extra_results)
        prev = self.units.get(unit_id)
        if prev is not None and prev.completed:
            # the stale completed entry for this id would alias the new
            # unit — rebuild the index before re-adding
            self._rebuild_open()
        elif prev is not None:
            # replacing a still-open unit: its entry is reused; detach the
            # old leases so the mirror stays exact (heap entries go stale
            # and are skipped on pop)
            for w in prev.leases:
                self._worker_leases.get(w, {}).pop(unit_id, None)
        self.units[unit_id] = wu
        if prev is None or prev.completed:
            self._open.append(unit_id)
            self._n_open += 1
        if self.tel.tracing:
            self.tel.event("submit", unit=unit_id, shard=self.shard_id,
                           replication=rep, quorum=quo)
        return wu

    def _rebuild_open(self) -> None:
        self._open = deque(uid for uid in self._open
                           if not self.units[uid].completed)
        self._open_stale = 0

    def _prune_open(self) -> None:
        # amortized: rebuild only when stale entries dominate
        if self._open_stale * 2 > len(self._open):
            self._rebuild_open()

    def _assignable(self, wu: WorkUnit, worker_id: str, now: float) -> bool:
        if wu.completed or worker_id in wu.results or worker_id in wu.leases:
            return False
        active = len(wu.leases) + len(wu.results)
        if active < wu.replication:
            return True
        # replica escalation: validation inconclusive (e.g. a corrupt result
        # broke the quorum) and nobody is working on it -> issue another copy
        if (not wu.leases and not wu.quorum_met()
                and len(wu.results) < wu.replication + wu.max_extra_results):
            return True
        # straggler duplicate: lease mostly elapsed, no result yet — at most
        # one duplicate per lease lifetime (the flag clears when a lease
        # expires or is dropped, i.e. when a new lifetime starts)
        if not wu.results and wu.leases and not wu.straggler_issued:
            oldest = min(wu.leases.values())
            if now - oldest > self.straggler_factor * wu.deadline_s:
                return True
        return False

    def _grant(self, wu: WorkUnit, worker_id: str, now: float) -> None:
        active = len(wu.leases) + len(wu.results)
        dup = bool(wu.leases) or bool(wu.results)
        straggler = (active >= wu.replication and not wu.results
                     and bool(wu.leases))
        wu.leases[worker_id] = now
        wu.ever_leased.add(worker_id)
        self._worker_leases.setdefault(worker_id, {})[wu.unit_id] = now
        heapq.heappush(self._lease_heap,
                       (now + wu.deadline_s, wu.unit_id, worker_id, now))
        if straggler:
            wu.straggler_issued = True
        self.metrics.dispatched.inc()
        if dup and len(wu.leases) + len(wu.results) > wu.replication:
            self.metrics.duplicates.inc()
        tel = self.tel
        if tel.tracing:
            tel.event("dispatch", unit=wu.unit_id, worker=worker_id,
                      shard=self.shard_id, dup=dup)
            tel.event("lease", unit=wu.unit_id, worker=worker_id,
                      shard=self.shard_id, deadline=now + wu.deadline_s)

    def _dispatch(self, worker_id: str, now: float) -> Optional[WorkUnit]:
        while self._open and self.units[self._open[0]].completed:
            self._open.popleft()           # head fast-path prune
            self._open_stale -= 1
        for uid in self._open:             # submit order, open units only
            wu = self.units[uid]
            if wu.completed:
                continue
            if self._assignable(wu, worker_id, now):
                self._grant(wu, worker_id, now)
                return wu
        return None

    def in_backoff(self, worker_id: str, now: Optional[float] = None) -> bool:
        info = self.workers.get(worker_id)
        if info is None:
            return False
        return (now if now is not None else self.clock()) < info.backoff_until

    def backoff(self, worker_id: str, now: Optional[float] = None) -> float:
        """Apply one exponential back-off step (paper §IV-C); -> delay."""
        info = self.join(worker_id)
        now = self.clock() if now is None else now
        info.backoff_k = min(info.backoff_k + 1, 12)
        delay = min(self.backoff_base_s * (2 ** info.backoff_k),
                    self.backoff_max_s)
        info.backoff_until = now + delay
        self.metrics.rejected_requests.inc()
        return delay

    def request_work(self, worker_id: str) -> Optional[WorkUnit]:
        """A volunteer asks for work (may be told to back off)."""
        if not self.tel.tracing:
            return self._request_work(worker_id)
        t0 = time.perf_counter()
        wu = self._request_work(worker_id)
        self._dispatch_hist.observe(time.perf_counter() - t0)
        return wu

    def _request_work(self, worker_id: str) -> Optional[WorkUnit]:
        now = self.clock()
        info = self.join(worker_id)
        if now < info.backoff_until:
            self.metrics.rejected_requests.inc()
            return None
        self._expire_leases(now)
        wu = self._dispatch(worker_id, now)
        if wu is not None:
            info.backoff_k = 0          # ONLY successful dispatch resets
            info.backoff_until = 0.0
            return wu
        self.backoff(worker_id, now)
        return None

    def request_batch(self, worker_id: str, max_units: int,
                      tail: bool = False) -> List[WorkUnit]:
        """Lease up to ``max_units`` assignable units in one index scan.

        The shard plane's watermark refill: one scan amortizes the cost of
        skipping a leased prefix over the whole batch.  ``tail=True`` scans
        newest-first — the work-stealing direction (steal from the tail of
        the victim's backlog, pytest-xdist style), so thieves and the
        owner's own refills collide as little as possible.  Does NOT apply
        back-off on an empty result: the caller (plane) decides after all
        refill sources are exhausted."""
        now = self.clock()
        info = self.join(worker_id)
        if now < info.backoff_until:
            self.metrics.rejected_requests.inc()
            return []
        self._expire_leases(now)
        got: List[WorkUnit] = []
        while self._open and self.units[self._open[0]].completed:
            self._open.popleft()
            self._open_stale -= 1
        it = reversed(self._open) if tail else iter(self._open)
        for uid in it:
            if len(got) >= max_units:
                break
            wu = self.units[uid]
            if wu.completed:
                continue
            if self._assignable(wu, worker_id, now):
                self._grant(wu, worker_id, now)
                got.append(wu)
        if got:
            info.backoff_k = 0
            info.backoff_until = 0.0
        return got

    # ---------------- results / validation ----------------
    def _accept_result(self, worker_id: str, unit_id: int,
                       result_hash: str) -> Optional[WorkUnit]:
        """Record one result; -> the unit if recorded, None if rejected."""
        wu = self.units.get(unit_id)
        if wu is None or wu.completed:
            return None
        if worker_id not in wu.ever_leased:
            # forged/free-riding report: this worker never held a lease on
            # the unit, so its "result" must not count toward quorum
            self.metrics.unsolicited_results.inc()
            if self.tel.tracing:
                self.tel.event("report_rejected", unit=unit_id,
                               worker=worker_id, shard=self.shard_id,
                               cause="unsolicited")
            return None
        if wu.leases.pop(worker_id, None) is not None:
            self._worker_leases.get(worker_id, {}).pop(unit_id, None)
        wu.results[worker_id] = result_hash
        if self.tel.tracing:
            self.tel.event("report", unit=unit_id, worker=worker_id,
                           shard=self.shard_id, result=result_hash[:16])
        return wu

    def _complete(self, wu: WorkUnit) -> None:
        """Quorum met: mint credit, retire the unit from the open index."""
        wu.completed = True
        self._n_open -= 1
        self._open_stale += 1
        self._prune_open()
        self._completed_log.append((wu.unit_id, wu.canonical))
        self.metrics.completed.inc()
        if self.tel.tracing:
            self.tel.event("quorum", unit=wu.unit_id, shard=self.shard_id,
                           canonical=wu.canonical[:16],
                           results=len(wu.results))
        n_canon = sum(1 for x in wu.results.values() if x == wu.canonical)
        for wid, h in wu.results.items():
            info = self.workers.get(wid)
            if info is None:
                continue
            if h == wu.canonical:
                info.completed += 1
                info.credit += 1.0 / max(1, n_canon)
            else:
                info.invalid += 1
                self.metrics.invalid_results.inc()
        # remaining leases are moot; clear them so the mirror stays exact
        for wid in wu.leases:
            self._worker_leases.get(wid, {}).pop(wu.unit_id, None)
        wu.leases.clear()

    def report(self, worker_id: str, unit_id: int, result_hash: str) -> bool:
        """Validator path: accept when ``quorum`` identical hashes exist."""
        wu = self._accept_result(worker_id, unit_id, result_hash)
        if wu is None:
            return False
        if wu.quorum_met():
            self._complete(wu)
            return True
        return False

    def report_batch(self, reports: Iterable[Tuple[str, int, str]]
                     ) -> List[tuple[int, str]]:
        """Apply a batch of (worker, unit, hash) results, then validate
        quorum once per touched unit instead of once per result — the
        per-round validation model the shard plane uses.  Results that
        arrive in the same batch as the quorum-completing one still count
        (credit splits over every canonical result in the batch); the
        conservation invariant — total completion credit == completed
        units — is unchanged.  -> newly completed (unit_id, canonical)."""
        touched: Dict[int, WorkUnit] = {}
        for worker_id, unit_id, result_hash in reports:
            wu = self._accept_result(worker_id, unit_id, result_hash)
            if wu is not None:
                touched[unit_id] = wu
        self.metrics.quorum_batches.inc()
        done: List[tuple[int, str]] = []
        for unit_id, wu in touched.items():
            if not wu.completed and wu.quorum_met():
                self._complete(wu)
                done.append((unit_id, wu.canonical))
        return done

    def _expire_leases(self, now: float) -> None:
        """Pop due leases off the deadline heap — O(expired), not O(open).

        A single large clock jump (SimClock advance) expires every due
        lease in one call; entries whose lease was already reported,
        dropped or superseded are skipped by the t0 check."""
        h = self._lease_heap
        while h and h[0][0] <= now:
            _, uid, worker_id, t0 = heapq.heappop(h)
            wu = self.units.get(uid)
            if (wu is None or wu.completed
                    or wu.leases.get(worker_id) != t0):
                continue                   # stale heap entry
            del wu.leases[worker_id]
            self._worker_leases.get(worker_id, {}).pop(uid, None)
            wu.reissues += 1
            wu.straggler_issued = False    # new lease lifetime begins
            self.metrics.lease_expiries.inc()
            self.metrics.reissued.inc()
            tel = self.tel
            if tel.tracing:
                eseq = tel.event("lease_expire", unit=uid,
                                 worker=worker_id, shard=self.shard_id)
                tel.event("reissue", unit=uid, worker=worker_id,
                          shard=self.shard_id, cause="lease_expire",
                          cause_seq=eseq)

    # ---------------- progress ----------------
    def open_backlog(self) -> int:
        """Exact count of not-yet-completed units — O(1)."""
        return self._n_open

    def pending(self) -> List[WorkUnit]:
        self._rebuild_open()
        return [self.units[uid] for uid in self._open]

    def done(self) -> bool:
        return self._n_open == 0

    def drain_completed(self) -> List[tuple[int, str]]:
        """(unit_id, canonical hash) pairs completed since the last drain.

        O(newly completed), unlike ``canonical_results()``'s scan of every
        unit ever submitted — the trainer's per-round result view."""
        out, self._completed_log = self._completed_log, []
        return out

    def credit_transfer(self, worker_id: str, moved_bytes: int,
                        dedup_bytes: int = 0) -> None:
        """Uplink credit: BOINC grants credit for work *delivered*; here a
        volunteer earns by the deduped bytes it actually moved (bytes the
        server already held cost it nothing and earn nothing)."""
        info = self.workers.get(worker_id)
        if info is None:
            return
        info.uplink_bytes += moved_bytes
        info.uplink_dedup += dedup_bytes
        info.credit += moved_bytes / float(1 << 20)   # 1 credit per MiB

    def canonical_results(self) -> Dict[int, str]:
        return {uid: u.canonical for uid, u in self.units.items()
                if u.completed}
