"""Volunteer work-unit scheduler (paper §II/§IV-C semantics).

BOINC's server distributes work units to untrusted, unreliable volunteers.
Production mechanics implemented here:

* leases with deadlines — a unit not reported by its deadline is re-issued;
* replication factor R + **quorum validation**: a unit is only accepted when
  ``quorum`` identical results arrive (results are hashes of deterministic
  computation, so agreement is bitwise — BOINC's validator);
* **exponential back-off**: a client whose request is rejected (server busy /
  no work) must wait 2^k * base seconds, protecting the server from request
  storms (paper §IV-C);
* **straggler mitigation**: when a unit's lease is mostly elapsed and spare
  capacity exists, a duplicate is dispatched and the first valid result wins;
* elastic membership: workers join/leave at any time; deterministic work
  units (data/pipeline.py) mean any replacement volunteer reproduces the
  exact result.

The scheduler is pure bookkeeping (no jax): the elastic trainer drives it
with real train-step executions.  Dispatch and lease expiry walk a pending
index (completed units leave it lazily), so ``request_work`` is O(1)
amortized regardless of how many units have ever been submitted —
``tasks_per_day_capacity`` feeds the paper's 8.8 M-tasks/day
server-throughput comparison.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class SimClock:
    """Deterministic clock for simulation/tests (advanced by the driver)."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@dataclass
class WorkUnit:
    unit_id: int
    payload: dict                      # e.g. {"batch_index": i, "step": s}
    replication: int = 1
    quorum: int = 1
    deadline_s: float = 60.0
    max_extra_results: int = 4         # replica escalation cap (BOINC's
                                       # max_error_results analogue)
    # runtime bookkeeping
    results: Dict[str, str] = field(default_factory=dict)   # worker -> hash
    leases: Dict[str, float] = field(default_factory=dict)  # worker -> t0
    completed: bool = False
    canonical: Optional[str] = None    # winning result hash
    reissues: int = 0

    def quorum_met(self) -> bool:
        counts: Dict[str, int] = {}
        for h in self.results.values():
            counts[h] = counts.get(h, 0) + 1
        for h, c in counts.items():
            if c >= self.quorum:
                self.canonical = h
                return True
        return False


@dataclass
class WorkerInfo:
    worker_id: str
    joined: float
    backoff_until: float = 0.0
    backoff_k: int = 0
    credit: float = 0.0          # beyond-paper: the credit system V-BOINC defers
    completed: int = 0
    invalid: int = 0
    uplink_bytes: int = 0        # deduped bytes this worker actually moved
    uplink_dedup: int = 0        # bytes the server already held for it
    alive: bool = True


class VolunteerScheduler:
    def __init__(self, *, replication: int = 1, quorum: int = 1,
                 deadline_s: float = 60.0, backoff_base_s: float = 0.5,
                 backoff_max_s: float = 60.0, straggler_factor: float = 0.8,
                 max_extra_results: int = 4, clock=time.time):
        assert quorum <= replication
        self.replication = replication
        self.quorum = quorum
        self.max_extra_results = max_extra_results
        self.deadline_s = deadline_s
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.units: Dict[int, WorkUnit] = {}
        # assignable/pending index: completed units leave this deque lazily
        # (pruned when a unit completes), so dispatch/expiry scan only open
        # units — O(1) amortized per request instead of O(all units ever)
        self._open: deque[int] = deque()
        self._open_dirty = False
        # incremental completion view: (unit_id, canonical hash) appended
        # as quorums are met, drained by the trainer each round — the
        # uplink analogue of the pending index (no O(all units) scans)
        self._completed_log: List[tuple[int, str]] = []
        self.workers: Dict[str, WorkerInfo] = {}
        self.stats = {"dispatched": 0, "completed": 0, "reissued": 0,
                      "duplicates": 0, "rejected_requests": 0,
                      "invalid_results": 0, "dropped_leases": 0}

    # ---------------- membership (elastic) ----------------
    def join(self, worker_id: str) -> WorkerInfo:
        info = self.workers.get(worker_id)
        if info is None or not info.alive:
            info = WorkerInfo(worker_id, self.clock())
            self.workers[worker_id] = info
        return info

    def leave(self, worker_id: str) -> None:
        info = self.workers.get(worker_id)
        if info is not None:
            info.alive = False
        # drop leases so units re-issue immediately (open units only)
        self._prune_open()
        for uid in self._open:
            unit = self.units[uid]
            if worker_id in unit.leases:
                del unit.leases[worker_id]
                self.stats["dropped_leases"] += 1

    # ---------------- unit lifecycle ----------------
    def submit(self, unit_id: int, payload: dict, *,
               replication: Optional[int] = None,
               quorum: Optional[int] = None) -> WorkUnit:
        wu = WorkUnit(unit_id, payload,
                      replication=replication or self.replication,
                      quorum=quorum or self.quorum,
                      deadline_s=self.deadline_s,
                      max_extra_results=self.max_extra_results)
        prev = self.units.get(unit_id)
        if prev is not None and prev.completed:
            self._prune_open()    # drop the stale entry before re-adding
        self.units[unit_id] = wu
        if prev is None or prev.completed:
            self._open.append(unit_id)
        return wu

    def _prune_open(self) -> None:
        if self._open_dirty:
            self._open = deque(uid for uid in self._open
                               if not self.units[uid].completed)
            self._open_dirty = False

    def _assignable(self, wu: WorkUnit, worker_id: str, now: float) -> bool:
        if wu.completed or worker_id in wu.results or worker_id in wu.leases:
            return False
        active = len(wu.leases) + len(wu.results)
        if active < wu.replication:
            return True
        # replica escalation: validation inconclusive (e.g. a corrupt result
        # broke the quorum) and nobody is working on it -> issue another copy
        if (not wu.leases and not wu.quorum_met()
                and len(wu.results) < wu.replication + wu.max_extra_results):
            return True
        # straggler duplicate: lease mostly elapsed, no result yet
        if not wu.results and wu.leases:
            oldest = min(wu.leases.values())
            if now - oldest > self.straggler_factor * wu.deadline_s:
                return True
        return False

    def request_work(self, worker_id: str) -> Optional[WorkUnit]:
        """A volunteer asks for work (may be told to back off)."""
        now = self.clock()
        info = self.join(worker_id)
        if now < info.backoff_until:
            self.stats["rejected_requests"] += 1
            return None
        self._expire_leases(now)
        for uid in self._open:                 # submit order, open units only
            wu = self.units[uid]
            if self._assignable(wu, worker_id, now):
                dup = bool(wu.leases) or bool(wu.results)
                wu.leases[worker_id] = now
                self.stats["dispatched"] += 1
                if dup and len(wu.leases) + len(wu.results) > wu.replication:
                    self.stats["duplicates"] += 1
                info.backoff_k = 0          # success resets back-off
                info.backoff_until = 0.0
                return wu
        # no work: exponential back-off (paper §IV-C)
        info.backoff_k = min(info.backoff_k + 1, 12)
        delay = min(self.backoff_base_s * (2 ** info.backoff_k),
                    self.backoff_max_s)
        info.backoff_until = now + delay
        self.stats["rejected_requests"] += 1
        return None

    def report(self, worker_id: str, unit_id: int, result_hash: str) -> bool:
        """Validator path: accept when ``quorum`` identical hashes exist."""
        wu = self.units.get(unit_id)
        if wu is None or wu.completed:
            return False
        wu.leases.pop(worker_id, None)
        wu.results[worker_id] = result_hash
        if wu.quorum_met():
            wu.completed = True
            self._open_dirty = True
            self._completed_log.append((unit_id, wu.canonical))
            self.stats["completed"] += 1
            for wid, h in wu.results.items():
                info = self.workers.get(wid)
                if info is None:
                    continue
                if h == wu.canonical:
                    info.completed += 1
                    info.credit += 1.0 / max(
                        1, sum(1 for x in wu.results.values()
                               if x == wu.canonical))
                else:
                    info.invalid += 1
                    self.stats["invalid_results"] += 1
            return True
        return False

    def _expire_leases(self, now: float) -> None:
        self._prune_open()
        for uid in self._open:
            wu = self.units[uid]
            expired = [w for w, t0 in wu.leases.items()
                       if now - t0 > wu.deadline_s]
            for w in expired:
                del wu.leases[w]
                wu.reissues += 1
                self.stats["reissued"] += 1

    # ---------------- progress ----------------
    def pending(self) -> List[WorkUnit]:
        self._prune_open()
        return [self.units[uid] for uid in self._open]

    def done(self) -> bool:
        self._prune_open()
        return not self._open

    def drain_completed(self) -> List[tuple[int, str]]:
        """(unit_id, canonical hash) pairs completed since the last drain.

        O(newly completed), unlike ``canonical_results()``'s scan of every
        unit ever submitted — the trainer's per-round result view."""
        out, self._completed_log = self._completed_log, []
        return out

    def credit_transfer(self, worker_id: str, moved_bytes: int,
                        dedup_bytes: int = 0) -> None:
        """Uplink credit: BOINC grants credit for work *delivered*; here a
        volunteer earns by the deduped bytes it actually moved (bytes the
        server already held cost it nothing and earn nothing)."""
        info = self.workers.get(worker_id)
        if info is None:
            return
        info.uplink_bytes += moved_bytes
        info.uplink_dedup += dedup_bytes
        info.credit += moved_bytes / float(1 << 20)   # 1 credit per MiB

    def canonical_results(self) -> Dict[int, str]:
        return {uid: u.canonical for uid, u in self.units.items()
                if u.completed}
