"""Continuous-batching serving engine.

Decode runs over a fixed pool of batch *slots*; requests are admitted into
free slots as others finish, each slot tracking its own sequence position
(the vectorized ``index`` path through ``attn_decode``).  Prefill is
compiled per prompt-length bucket (serving systems bucket prompts; the
compile cache is keyed by length), and the per-request cache strip is
inserted into the pool cache at the slot's batch row.

The engine runs inside a CapsuleRuntime, so the capsule's control verbs
(pause/snapshot) apply to serving exactly as to training — the paper's
"run typical BOINC projects" with the inference workload.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import telemetry as tlm
from repro.models import api
from repro.models.lm import RunConfig


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    submitted: float = field(default_factory=time.perf_counter)
    # filled by the engine
    output: List[int] = field(default_factory=list)
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 256, run: RunConfig = RunConfig()):
        if cfg.enc_dec:
            raise NotImplementedError("engine serves decoder-only archs")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.run = run
        self._decode = jax.jit(api.make_decode_step(cfg, run))
        self._prefill_cache: Dict[int, callable] = {}
        # pool caches: batch dim = slots
        self.caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            api.cache_specs(cfg, slots, max_len),
            is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
        self.lengths = np.zeros(slots, np.int32)      # per-slot position
        self.active: List[Optional[Request]] = [None] * slots
        scope = tlm.get_default().scope("serving")
        self.metrics = scope.counters("served", "decode_steps", "prefills")
        self.stats = scope.view()

    # ------------------------------------------------------------------
    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(
                api.make_prefill_step(self.cfg, self.max_len, self.run))
        return self._prefill_cache[length]

    def _admit(self, slot: int, req: Request) -> None:
        t = len(req.prompt)
        logits, cache = self._prefill_fn(t)(
            self.params, {"tokens": req.prompt[None, :]})
        self.metrics.prefills.inc()
        # insert the request's cache strip at the slot's batch row
        def insert(pool, strip):
            return pool.at[:, slot].set(strip[:, 0].astype(pool.dtype))
        self.caches = jax.tree.map(insert, self.caches, cache)
        tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        req.output.append(tok)
        req.first_token_s = time.perf_counter() - req.submitted
        self.lengths[slot] = t
        self.active[slot] = req

    def _retire(self, slot: int) -> Request:
        req = self.active[slot]
        req.done_s = time.perf_counter() - req.submitted
        self.active[slot] = None
        self.lengths[slot] = 0
        self.metrics.served.inc()
        return req

    # ------------------------------------------------------------------
    def run_queue(self, requests: List[Request]) -> List[Request]:
        """Serve a queue to completion; returns finished requests."""
        pending = list(requests)
        finished: List[Request] = []
        while pending or any(r is not None for r in self.active):
            # admit into free slots
            for slot in range(self.slots):
                if self.active[slot] is None and pending:
                    self._admit(slot, pending.pop(0))
            # batched decode over every active slot (inactive rows compute
            # too — slot masking, the standard continuous-batching cost)
            tokens = np.zeros((self.slots, 1), np.int32)
            for slot, req in enumerate(self.active):
                if req is not None:
                    tokens[slot, 0] = req.output[-1]
            logits, self.caches = self._decode(
                self.params, self.caches,
                {"tokens": jnp.asarray(tokens),
                 "index": jnp.asarray(self.lengths)})
            self.metrics.decode_steps.inc()
            nxt = np.asarray(
                jnp.argmax(logits[:, 0, :self.cfg.vocab_size], axis=-1))
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                self.lengths[slot] += 1
                req.output.append(int(nxt[slot]))
                if (len(req.output) >= req.max_new_tokens
                        or self.lengths[slot] + 1 >= self.max_len):
                    finished.append(self._retire(slot))
        return finished
