"""GQA attention block: param specs + train/prefill/decode application.

KV caches use layout (B, S, K, hd) with the cache length dim sharded on the
model axis (``cache_len`` rule) — always divisible (32k / 512k) even when the
KV head count (2..8) is not, which keeps decode_32k / long_500k cache memory
per device bounded.  Attention math runs on GQA-repeated heads; GSPMD slices
the repeat locally (see layers.repeat_kv).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import TensorSpec, constrain
from repro.models import layers
from repro.models.layers import blocked_attention, decode_attention, rotary


class KVCache(NamedTuple):
    k: jax.Array        # (B, S, K, hd)
    v: jax.Array        # (B, S, K, hd)


def attn_specs(cfg: ArchConfig) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    out = {
        "wq": TensorSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": TensorSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": TensorSpec((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": TensorSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = TensorSpec((h, hd), ("heads", "head_dim"), init="zeros")
        out["bk"] = TensorSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = TensorSpec((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return out


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> KVCache:
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, k, hd)
    axes = (None, "batch", "cache_len", "cache_heads", "head_dim")
    return KVCache(TensorSpec(shape, axes, dtype),
                   TensorSpec(shape, axes, dtype))


def _qkv(p: dict, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(p: dict, x: jax.Array, cfg: ArchConfig,
               positions: jax.Array, *, causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / encoder)."""
    q, k, v = _qkv(p, x, cfg, positions)
    rep = cfg.n_heads // cfg.n_kv_heads
    out = blocked_attention(q, layers.repeat_kv(k, rep),
                            layers.repeat_kv(v, rep),
                            causal=causal, window=cfg.window)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


def attn_prefill(p: dict, x: jax.Array, cfg: ArchConfig,
                 positions: jax.Array) -> tuple[jax.Array, KVCache]:
    """Causal attention that also returns the layer's KV cache."""
    q, k, v = _qkv(p, x, cfg, positions)
    rep = cfg.n_heads // cfg.n_kv_heads
    out = blocked_attention(q, layers.repeat_kv(k, rep),
                            layers.repeat_kv(v, rep),
                            causal=True, window=cfg.window)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k, v)


def _cache_write(cache: jax.Array, new: jax.Array,
                 index: jax.Array) -> jax.Array:
    """Masked in-place token write.

    A ``dynamic_update_slice`` at a traced index on the len-sharded cache
    dim forces GSPMD into an 'involuntary full rematerialization' (all-
    gather the whole cache, update, re-shard — GBs per layer per token).
    The masked ``where`` keeps every shard's update local: broadcast the
    (B, 1, K, hd) token against the len-sharded cache and select by
    position.  Costs one cache read+write of HBM traffic (which decode
    attention pays anyway), moves ZERO collective bytes.

    ``index``: () shared position, or (B,) per-sequence positions
    (continuous batching — each slot is at its own length).
    """
    s = cache.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)[None, :, None, None]
    idx = index if index.ndim == 0 else index[:, None, None, None]
    return jnp.where(pos == idx, new.astype(cache.dtype), cache)


def attn_decode(p: dict, x: jax.Array, cfg: ArchConfig, cache: KVCache,
                index: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token decode step.  x: (B, 1, D); index: () or (B,) lengths."""
    b = x.shape[0]
    index = jnp.asarray(index, jnp.int32)
    positions = jnp.full((b, 1), index, jnp.int32) if index.ndim == 0 \
        else index[:, None]
    q, k, v = _qkv(p, x, cfg, positions)
    # q is tiny: replicate it across the model axis so the scores einsum
    # keeps the CACHE's len-sharding instead of resharding the cache onto
    # q's head sharding (50 KB gather vs GBs).
    q = constrain(q, ("act_batch", None, None, None))
    k = constrain(k, ("act_batch", None, None, None))
    v = constrain(v, ("act_batch", None, None, None))
    k_cache = _cache_write(cache.k, k, index)
    v_cache = _cache_write(cache.v, v, index)
    rep = cfg.n_heads // cfg.n_kv_heads
    kv_len = jnp.full((b,), index + 1, jnp.int32) if index.ndim == 0 \
        else index + 1
    out = decode_attention(q, layers.repeat_kv(k_cache, rep),
                           layers.repeat_kv(v_cache, rep), kv_len=kv_len,
                           window=cfg.window)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k_cache, v_cache)
