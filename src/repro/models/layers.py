"""Model primitives: norms, rotary, blocked attention, SwiGLU MLP.

All functions are pure and operate on *global* (unsharded) shapes; GSPMD
partitions them according to the sharding resolver's annotations.  Attention
uses an online-softmax blocked formulation (the jnp twin of the Pallas
flash-attention kernel in ``repro.kernels.flash_attention``) so that 32k+
contexts never materialize a full (T, S) score matrix.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import TensorSpec, constrain

NEG_INF = -1e30


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    # variance in f32, but x itself is NOT upcast: a whole-tensor convert
    # here gets fused below the TP partial-sum all-reduces by XLA, doubling
    # every collective's bytes (EXPERIMENTS.md §Perf cell B iter6).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate.astype(x.dtype)) * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def mlp_specs(d_model: int, d_ff: int) -> dict:
    """SwiGLU params; embed dim FSDP-sharded, ff dim tensor-parallel."""
    return {
        "w_gate": TensorSpec((d_model, d_ff), ("embed", "ff")),
        "w_up": TensorSpec((d_model, d_ff), ("embed", "ff")),
        "w_down": TensorSpec((d_ff, d_model), ("ff", "embed")),
    }


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*n_rep, hd).  GSPMD slices the repeated head
    dim locally when it is sharded, so no device materializes all heads."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)) \
              .reshape(b, s, kh * n_rep, hd)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset=0,
                      kv_len: Optional[jax.Array] = None,
                      window: int = 0, block_kv: int = 1024,
                      scale: Optional[float] = None) -> jax.Array:
    """Online-softmax attention over KV blocks.

    q: (B, T, H, hd);  k, v: (B, S, H, hd)  (already GQA-repeated).
    ``q_offset``: absolute position of q[0] (decode / chunked prefill).
    ``kv_len``: (B,) valid cache lengths (decode); None = all valid.
    ``window``: sliding-window size (0 = full).
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_kv = min(block_kv, s)
    if s % block_kv:
        pad = block_kv - s % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((b,), s, jnp.int32)
        s = s + pad
    nblk = s // block_kv

    pos_q = q_offset + jnp.arange(t, dtype=jnp.int32)             # (T,)

    def body(carry, blk):
        m, l, acc = carry
        start = blk * block_kv
        kb = lax.dynamic_slice_in_dim(k, start, block_kv, axis=1)
        vb = lax.dynamic_slice_in_dim(v, start, block_kv, axis=1)
        # inputs stay in compute dtype (bf16 collectives upstream); the MXU
        # accumulates in f32 via preferred_element_type
        scores = jnp.einsum("bthd,bshd->bhts", q, kb,
                            preferred_element_type=jnp.float32) * scale
        pos_k = start + jnp.arange(block_kv, dtype=jnp.int32)     # (Sb,)
        mask = jnp.ones((t, block_kv), bool)
        if causal:
            mask &= pos_k[None, :] <= pos_q[:, None]
        if window:
            mask &= pos_k[None, :] > pos_q[:, None] - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        if kv_len is not None:
            lmask = pos_k[None, :] < kv_len[:, None]              # (B,Sb)
            scores = jnp.where(lmask[:, None, None, :], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(-1))                    # (B,H,T)
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t), jnp.float32)
    a0 = jnp.zeros((b, h, t, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nblk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]                  # (B,H,T,hd)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)              # (B,T,H,hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     kv_len: jax.Array, window: int = 0,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-step attention over a full cache (no blocking; scores are
    (B, H, 1, S) which stays small even at 500k once S is mesh-sharded).

    q: (B, 1, H, hd); caches: (B, S, H, hd) (GQA-repeated); kv_len: (B,).
    """
    b, t, h, hd = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bthd,bshd->bhts", q, k_cache,
                        preferred_element_type=jnp.float32) * scale
    # scores inherit the cache's len-sharding; softmax reduces over the
    # sharded dim with tiny (B,H,T) collectives
    scores = constrain(scores, ("act_batch", None, None, "cache_len"))
    pos_k = jnp.arange(s, dtype=jnp.int32)
    mask = pos_k[None, :] < kv_len[:, None]                       # (B,S)
    if window:  # sliding-window: only the last `window` positions attend
        mask &= pos_k[None, :] >= kv_len[:, None] - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          vocab_size: int) -> jax.Array:
    """Mean CE per token; logits may be vocab-padded (padded cols masked)."""
    padded = logits.shape[-1]
    if padded != vocab_size:
        col = jnp.arange(padded)
        logits = jnp.where(col[None, None, :] < vocab_size, logits, NEG_INF)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
