"""Encoder–decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, D) directly to the encoder.  The
decoder is a causal transformer with cross-attention to the encoder output;
decode shapes use a self-attention KV cache of ``seq_len`` plus a fixed
cross-attention KV computed once from the encoder (ENC_LEN_DECODE frames).
RMSNorm is used throughout for uniformity with the other archs (deviation
from the source LayerNorm, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import TensorSpec, constrain, stack_specs
from repro.models import attention, layers
from repro.models.attention import KVCache
from repro.models.lm import ACT, RunConfig, cast_tree, unembed

# encoder frames backing a decode-time cross-attention cache
ENC_LEN_DECODE = 4096


def enc_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attention.attn_specs(cfg),
        "ln2": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff),
    }


def dec_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln1": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "self_attn": attention.attn_specs(cfg),
        "ln_x": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "cross_attn": attention.attn_specs(cfg),
        "ln2": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff),
    }


def encdec_specs(cfg: ArchConfig) -> dict:
    vp = cfg.padded_vocab()
    return {
        "embed": TensorSpec((vp, cfg.d_model), ("vocab", "embed")),
        "enc_layers": stack_specs(enc_block_specs(cfg), cfg.n_layers),
        "dec_layers": stack_specs(dec_block_specs(cfg), cfg.n_layers),
        "enc_norm": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "final_norm": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": TensorSpec((cfg.d_model, vp), ("embed", "vocab")),
    }


def encdec_cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                       enc_len: int = ENC_LEN_DECODE) -> dict:
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    self_shape = (cfg.n_layers, batch, max_len, k, hd)
    cross_shape = (cfg.n_layers, batch, enc_len, k, hd)
    axes = (None, "batch", "cache_len", "cache_heads", "head_dim")
    return {
        "self_kv": KVCache(TensorSpec(self_shape, axes, jnp.bfloat16),
                           TensorSpec(self_shape, axes, jnp.bfloat16)),
        "cross_kv": KVCache(TensorSpec(cross_shape, axes, jnp.bfloat16),
                            TensorSpec(cross_shape, axes, jnp.bfloat16)),
    }


def encode(params: dict, cfg: ArchConfig, frames: jax.Array,
           run: RunConfig = RunConfig()) -> jax.Array:
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend)."""
    x = constrain(frames.astype(run.compute_dtype), ACT)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    enc_params = cast_tree(params["enc_layers"], run.compute_dtype)

    def body(x, lp):
        xn = layers.rms_norm(x, lp["ln1"], cfg.rms_eps)
        x = x + attention.attn_train(lp["attn"], xn, cfg, positions,
                                     causal=False)
        xn2 = layers.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m = lp["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
        return constrain(x, ACT), None

    policy = run.remat_policy()
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, _ = lax.scan(body, x, enc_params)
    return layers.rms_norm(x, params["enc_norm"], cfg.rms_eps)


def _cross_attend(lp: dict, xn: jax.Array, cfg: ArchConfig,
                  enc_kv: Optional[KVCache], enc_out: Optional[jax.Array],
                  enc_len: Optional[jax.Array] = None) -> jax.Array:
    """Cross-attention: q from decoder, k/v from encoder output or cache."""
    dt = xn.dtype
    q = jnp.einsum("btd,dhk->bthk", xn, lp["wq"].astype(dt))
    if enc_kv is None:
        k = jnp.einsum("btd,dhk->bthk", enc_out, lp["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", enc_out, lp["wv"].astype(dt))
    else:
        k, v = enc_kv.k.astype(dt), enc_kv.v.astype(dt)
    rep = cfg.n_heads // cfg.n_kv_heads
    k, v = layers.repeat_kv(k, rep), layers.repeat_kv(v, rep)
    if xn.shape[1] == 1:
        kv_len = enc_len if enc_len is not None else \
            jnp.full((xn.shape[0],), k.shape[1], jnp.int32)
        out = layers.decode_attention(q, k, v, kv_len=kv_len)
    else:
        out = layers.blocked_attention(q, k, v, causal=False)
    return jnp.einsum("bthk,hkd->btd", out, lp["wo"].astype(dt))


def forward_train(params: dict, cfg: ArchConfig, frames: jax.Array,
                  tokens: jax.Array, run: RunConfig = RunConfig()):
    """Teacher-forced training forward.  frames: (B,S,D); tokens: (B,T)."""
    enc_out = encode(params, cfg, frames, run)
    x = constrain(params["embed"].astype(run.compute_dtype)[tokens], ACT)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    dec_params = cast_tree(params["dec_layers"], run.compute_dtype)

    def body(x, lp):
        xn = layers.rms_norm(x, lp["ln1"], cfg.rms_eps)
        x = x + attention.attn_train(lp["self_attn"], xn, cfg, positions)
        xc = layers.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + _cross_attend(lp["cross_attn"], xc, cfg, None, enc_out)
        xn2 = layers.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m = lp["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
        return constrain(x, ACT), None

    policy = run.remat_policy()
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, _ = lax.scan(body, x, dec_params)
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = constrain(unembed(params, cfg, x),
                       ("act_batch", "act_seq", "act_vocab"))
    return logits, {}


def prefill(params: dict, cfg: ArchConfig, frames: jax.Array,
            tokens: jax.Array, max_len: int, run: RunConfig = RunConfig()):
    """Encode + teacher-forced decoder pass building both caches."""
    enc_out = encode(params, cfg, frames, run)
    x = constrain(params["embed"].astype(run.compute_dtype)[tokens], ACT)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    dec_params = cast_tree(params["dec_layers"], run.compute_dtype)

    def body(x, lp):
        dt = x.dtype
        xn = layers.rms_norm(x, lp["ln1"], cfg.rms_eps)
        a, self_kv = attention.attn_prefill(lp["self_attn"], xn, cfg,
                                            positions)
        x = x + a
        xc = layers.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        ck = jnp.einsum("btd,dhk->bthk", enc_out,
                        lp["cross_attn"]["wk"].astype(dt))
        cv = jnp.einsum("btd,dhk->bthk", enc_out,
                        lp["cross_attn"]["wv"].astype(dt))
        cross_kv = KVCache(ck.astype(jnp.bfloat16), cv.astype(jnp.bfloat16))
        x = x + _cross_attend(lp["cross_attn"], xc, cfg, cross_kv, None)
        xn2 = layers.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m = lp["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
        pad = ((0, 0), (0, max_len - t), (0, 0), (0, 0))
        self_kv = KVCache(jnp.pad(self_kv.k.astype(jnp.bfloat16), pad),
                          jnp.pad(self_kv.v.astype(jnp.bfloat16), pad))
        return constrain(x, ACT), {"self_kv": self_kv, "cross_kv": cross_kv}

    x, caches = lax.scan(body, x, dec_params)
    x = layers.rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, x)[:, 0], caches


def decode_step(params: dict, cfg: ArchConfig, caches: dict,
                tokens: jax.Array, index: jax.Array,
                run: RunConfig = RunConfig()):
    """One-token decoder step against frozen cross-attention caches."""
    x = constrain(params["embed"].astype(run.compute_dtype)[tokens], ACT)
    dec_params = cast_tree(params["dec_layers"], run.compute_dtype)

    def body(x, lp_cache):
        lp, cache = lp_cache
        xn = layers.rms_norm(x, lp["ln1"], cfg.rms_eps)
        a, self_kv = attention.attn_decode(lp["self_attn"], xn, cfg,
                                           cache["self_kv"], index)
        x = x + a
        xc = layers.rms_norm(x, lp["ln_x"], cfg.rms_eps)
        x = x + _cross_attend(lp["cross_attn"], xc, cfg, cache["cross_kv"],
                              None)
        xn2 = layers.rms_norm(x, lp["ln2"], cfg.rms_eps)
        m = lp["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
        return constrain(x, ACT), {"self_kv": self_kv,
                                   "cross_kv": cache["cross_kv"]}

    x, new_caches = lax.scan(body, x, (dec_params, caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, x), new_caches
