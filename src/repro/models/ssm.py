"""Mamba1 selective-SSM block (falcon-mamba / hymba SSM heads).

Training/prefill uses a *chunked* scan: sequential ``lax.scan`` over time
chunks carrying the (B, D_inner, N) state, with an associative scan inside
each chunk — memory O(chunk) instead of O(T), and the jnp twin of the Pallas
kernel in ``repro.kernels.ssm_scan``.  Decode is the O(1) recurrence update.

TPU adaptation: the depthwise causal conv is expressed as a sum of shifted
scaled copies (VPU-friendly; no im2col), and d_inner is tensor-parallel over
the model axis (state dim N=16 stays local).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import TensorSpec


class SSMCache(NamedTuple):
    conv: jax.Array     # (B, d_conv-1, Di) last inputs for the causal conv
    h: jax.Array        # (B, Di, N) recurrent state


def ssm_specs(cfg: ArchConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm.d_state
    r, dc = cfg.dt_rank, cfg.ssm.d_conv
    return {
        "in_proj": TensorSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": TensorSpec((dc, di), ("conv", "inner")),
        "conv_b": TensorSpec((di,), ("inner",), init="zeros"),
        "x_proj": TensorSpec((di, r + 2 * n), ("inner", None)),
        "dt_proj": TensorSpec((r, di), ("dt_rank", "inner")),
        "dt_bias": TensorSpec((di,), ("inner",), init="ones"),
        "A_log": TensorSpec((di, n), ("inner", "state"), init="slow_decay"),
        "D": TensorSpec((di,), ("inner",), init="ones"),
        "out_proj": TensorSpec((di, d), ("inner", "embed")),
    }


def ssm_cache_specs(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    di, n, dc = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    return SSMCache(
        conv=TensorSpec((cfg.n_layers, batch, dc - 1, di),
                        (None, "batch", None, "inner"), dtype),
        h=TensorSpec((cfg.n_layers, batch, di, n),
                     (None, "batch", "inner", "state"), dtype),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d via shifted adds.  x: (B, T, Di); w: (dc, Di)."""
    dc = w.shape[0]
    out = x * w[-1].astype(x.dtype)
    for i in range(1, dc):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[dc - 1 - i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _ssm_params(p: dict, xc: jax.Array, cfg: ArchConfig):
    """Input-dependent (dt, B, C) + discretized (Abar, Bx)."""
    n = cfg.ssm.d_state
    r = cfg.dt_rank
    dbc = xc.astype(jnp.float32) @ p["x_proj"].astype(jnp.float32)
    dt, bm, cm = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (..., Di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (Di, N)
    abar = jnp.exp(dt[..., None] * a)                             # (..., Di, N)
    bx = (dt * xc.astype(jnp.float32))[..., :, None] * bm[..., None, :]
    return abar, bx, cm


def ssm_train(p: dict, x: jax.Array, cfg: ArchConfig,
              chunk: int = 256, return_state: bool = False):
    """Full-sequence selective scan.  x: (B, T, D) -> (B, T, D).

    With ``return_state`` also returns the final SSMCache (prefill)."""
    b, t, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm.d_state
    xz = x @ p["in_proj"].astype(x.dtype)                         # (B,T,2Di)
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xr, p["conv_w"], p["conv_b"]))  # (B,T,Di)

    chunk = min(chunk, t)
    if t % chunk:  # pad time to a chunk multiple (masked by abar=1,bx=0)
        pad = chunk - t % chunk
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    else:
        pad, xc_p = 0, xc
    tt = xc_p.shape[1]
    nchunk = tt // chunk

    abar_full, bx_full, cm_full = _ssm_params(p, xc_p, cfg)
    if pad:  # identity transition on padded steps so h_final stays exact
        valid = (jnp.arange(tt) < t)[None, :, None, None]
        abar_full = jnp.where(valid, abar_full, 1.0)
        bx_full = jnp.where(valid, bx_full, 0.0)
    # reshape to (nchunk, B, chunk, ...) for a sequential scan over chunks
    def to_chunks(a):
        return a.reshape(b, nchunk, chunk, *a.shape[2:]).swapaxes(0, 1)
    abar_c, bx_c, cm_c = map(to_chunks, (abar_full, bx_full, cm_full))

    def chunk_body(h, inputs):
        abar, bx, cm = inputs                                     # (B,chunk,Di,N)
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        a_cum, b_cum = lax.associative_scan(combine, (abar, bx), axis=1)
        hs = a_cum * h[:, None] + b_cum                           # (B,chunk,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cm)                   # (B,chunk,Di)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_final, ys = lax.scan(chunk_body, h0, (abar_c, bx_c, cm_c))
    y = ys.swapaxes(0, 1).reshape(b, tt, di)[:, :t]
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    dc = cfg.ssm.d_conv
    conv_tail = jnp.pad(xr, ((0, 0), (dc - 1, 0), (0, 0)))[:, t:t + dc - 1]
    # NOTE: padded tail positions (t % chunk != 0) were folded with bx=0 pads,
    # but abar pads are exp(dt(0)*A) != 1 — mask below keeps h exact.
    return out, SSMCache(conv=conv_tail.astype(jnp.float32), h=h_final)


def ssm_decode(p: dict, x: jax.Array, cfg: ArchConfig,
               cache: SSMCache) -> tuple[jax.Array, SSMCache]:
    """One-token recurrence.  x: (B, 1, D)."""
    xz = x @ p["in_proj"].astype(x.dtype)
    xr, z = jnp.split(xz, 2, axis=-1)                             # (B,1,Di)
    # causal conv over [conv_state, x]
    window = jnp.concatenate([cache.conv.astype(x.dtype), xr], axis=1)  # (B,dc,Di)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(x.dtype)) \
        + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)[:, None]                                 # (B,1,Di)
    abar, bx, cm = _ssm_params(p, xc, cfg)                        # (B,1,Di,N)
    h = abar[:, 0] * cache.h + bx[:, 0]                           # (B,Di,N)
    y = jnp.einsum("bdn,bn->bd", h, cm[:, 0])[:, None]            # (B,1,Di)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, SSMCache(conv=window[:, 1:].astype(cache.conv.dtype), h=h)
