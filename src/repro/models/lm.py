"""Unified decoder-only language model covering all assigned families.

One parameterized module: dense GQA (internlm2/granite/qwen2/minitron),
MoE (deepseek/qwen3), SSM (falcon-mamba), hybrid attn+SSM (hymba) and the
early-fusion VLM backbone (chameleon — VQ image ids share the token vocab;
the VQ tokenizer itself is the stubbed frontend).  Layers are stacked and
scanned (``lax.scan``) so the HLO stays compact for 48–64 layer configs; the
per-layer body is optionally rematerialized.

Everything is a pure function over an explicit ``TensorSpec`` param tree —
this *is* the "compile once, run on any volunteer mesh" property the capsule
layer (repro.core.capsule) relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import TensorSpec, constrain, stack_specs
from repro.models import attention, layers, ssm
from repro.models.attention import KVCache
from repro.moe.moe import moe_apply, moe_specs

ACT = ("act_batch", "act_seq", "act_embed")


def cast_tree(tree, dtype):
    """Cast float params to the compute dtype BEFORE the layer scan so FSDP
    all-gathers move bf16, not f32 (halves gather traffic and temp memory)."""
    def c(a):
        return a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a
    return jax.tree.map(c, tree)


def gather_weights(lp: dict, run: RunConfig) -> dict:
    """FSDP gather-then-compute (RunConfig.fsdp_gather_weights)."""
    if not run.fsdp_gather_weights:
        return lp
    return jax.tree.map(lambda a: constrain(a, (None,) * a.ndim), lp)


@dataclass(frozen=True)
class RunConfig:
    """Execution knobs (perf-iteration surface; see EXPERIMENTS.md §Perf)."""
    remat: str = "full"              # none | full | dots
    block_kv: int = 1024
    ssm_chunk: int = 256
    capacity_factor: float = 1.25
    compute_dtype: Any = jnp.bfloat16
    logical_rules: Optional[dict] = None   # sharding-rule overrides
    # FSDP semantics: gather each layer's (sharded) weights to replicated
    # right before use — forbids GSPMD's split-K fallback (partial-sum
    # all-reduces of full activations; see EXPERIMENTS.md §Perf cell B)
    fsdp_gather_weights: bool = False

    def remat_policy(self):
        if self.remat == "none":
            return None
        if self.remat == "dots":
            return jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# Param / cache specs
# ---------------------------------------------------------------------------
def block_specs(cfg: ArchConfig) -> dict:
    out: dict = {"ln1": TensorSpec((cfg.d_model,), ("embed",), init="ones")}
    if cfg.family == "ssm":
        out["ssm"] = ssm.ssm_specs(cfg)
        return out
    out["attn"] = attention.attn_specs(cfg)
    if cfg.family == "hybrid":
        out["ssm"] = ssm.ssm_specs(cfg)
        out["norm_attn"] = TensorSpec((cfg.d_model,), ("embed",), init="ones")
        out["norm_ssm"] = TensorSpec((cfg.d_model,), ("embed",), init="ones")
    out["ln2"] = TensorSpec((cfg.d_model,), ("embed",), init="ones")
    if cfg.is_moe:
        out["moe"] = moe_specs(cfg)
    else:
        out["mlp"] = layers.mlp_specs(cfg.d_model, cfg.d_ff)
    return out


def lm_specs(cfg: ArchConfig) -> dict:
    vp = cfg.padded_vocab()
    out = {
        "embed": TensorSpec((vp, cfg.d_model), ("vocab", "embed")),
        "layers": stack_specs(block_specs(cfg), cfg.n_layers),
        "final_norm": TensorSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = TensorSpec((cfg.d_model, vp), ("embed", "vocab"))
    return out


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    out: dict = {}
    if cfg.family != "ssm":
        out["kv"] = attention.cache_specs(cfg, batch, max_len)
    if cfg.family in ("ssm", "hybrid"):
        out["ssm"] = ssm.ssm_cache_specs(cfg, batch)
    return out


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _block_train(cfg: ArchConfig, run: RunConfig, p: dict, x: jax.Array,
                 positions: jax.Array, causal: bool = True):
    metrics = {}
    xn = layers.rms_norm(x, p["ln1"], cfg.rms_eps)
    if cfg.family == "ssm":
        return x + ssm.ssm_train(p["ssm"], xn, cfg, run.ssm_chunk), metrics
    if cfg.family == "hybrid":
        a = attention.attn_train(p["attn"], xn, cfg, positions, causal=causal)
        s = ssm.ssm_train(p["ssm"], xn, cfg, run.ssm_chunk)
        x = x + 0.5 * (layers.rms_norm(a, p["norm_attn"], cfg.rms_eps)
                       + layers.rms_norm(s, p["norm_ssm"], cfg.rms_eps))
    else:
        x = x + attention.attn_train(p["attn"], xn, cfg, positions,
                                     causal=causal)
    xn2 = layers.rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.is_moe:
        y, metrics = moe_apply(p["moe"], xn2, cfg, run.capacity_factor)
        x = x + y
    else:
        m = p["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
    return x, metrics


def _block_decode(cfg: ArchConfig, run: RunConfig, p: dict, x: jax.Array,
                  cache: dict, index: jax.Array):
    new_cache = {}
    xn = layers.rms_norm(x, p["ln1"], cfg.rms_eps)
    if cfg.family == "ssm":
        y, new_cache["ssm"] = ssm.ssm_decode(p["ssm"], xn, cfg, cache["ssm"])
        return x + y, new_cache
    if cfg.family == "hybrid":
        a, new_cache["kv"] = attention.attn_decode(p["attn"], xn, cfg,
                                                   cache["kv"], index)
        s, new_cache["ssm"] = ssm.ssm_decode(p["ssm"], xn, cfg, cache["ssm"])
        x = x + 0.5 * (layers.rms_norm(a, p["norm_attn"], cfg.rms_eps)
                       + layers.rms_norm(s, p["norm_ssm"], cfg.rms_eps))
    else:
        a, new_cache["kv"] = attention.attn_decode(p["attn"], xn, cfg,
                                                   cache["kv"], index)
        x = x + a
    xn2 = layers.rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.is_moe:
        y, _ = moe_apply(p["moe"], xn2, cfg, run.capacity_factor)
        x = x + y
    else:
        m = p["mlp"]
        x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
    return x, new_cache


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------
def embed_tokens(params: dict, cfg: ArchConfig, tokens: jax.Array,
                 dtype) -> jax.Array:
    return params["embed"].astype(dtype)[tokens]


def unembed(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    return x @ params["lm_head"].astype(x.dtype)


def forward_train(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  run: RunConfig = RunConfig(), *, causal: bool = True,
                  inputs_embeds: Optional[jax.Array] = None):
    """tokens: (B, T) -> (logits (B,T,Vp), metrics)."""
    x = inputs_embeds if inputs_embeds is not None else \
        embed_tokens(params, cfg, tokens, run.compute_dtype)
    x = constrain(x, ACT)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    layer_params = cast_tree(params["layers"], run.compute_dtype)

    def body(x, lp):
        lp = gather_weights(lp, run)
        x, metrics = _block_train(cfg, run, lp, x, positions, causal)
        return constrain(x, ACT), metrics

    policy = run.remat_policy()
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, ms = lax.scan(body, x, layer_params)
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = constrain(unembed(params, cfg, x),
                       ("act_batch", "act_seq", "act_vocab"))
    metrics = {k: jnp.mean(v) for k, v in ms.items()} if ms else {}
    return logits, metrics


def prefill(params: dict, cfg: ArchConfig, tokens: jax.Array, max_len: int,
            run: RunConfig = RunConfig()):
    """Build caches for ``tokens`` and return last-position logits.

    Returns (logits (B, Vp), caches).  Cache buffers are allocated at
    ``max_len`` so decode can continue in place.
    """
    x = constrain(embed_tokens(params, cfg, tokens, run.compute_dtype), ACT)
    b, t = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    layer_params = cast_tree(params["layers"], run.compute_dtype)

    def body(x, lp):
        lp = gather_weights(lp, run)
        new_cache = {}
        xn = layers.rms_norm(x, lp["ln1"], cfg.rms_eps)
        if cfg.family == "ssm":
            y, new_cache["ssm"] = ssm.ssm_train(lp["ssm"], xn, cfg,
                                                run.ssm_chunk, True)
            x = x + y
        elif cfg.family == "hybrid":
            a, kv = attention.attn_prefill(lp["attn"], xn, cfg, positions)
            s, new_cache["ssm"] = ssm.ssm_train(lp["ssm"], xn, cfg,
                                                run.ssm_chunk, True)
            x = x + 0.5 * (layers.rms_norm(a, lp["norm_attn"], cfg.rms_eps)
                           + layers.rms_norm(s, lp["norm_ssm"], cfg.rms_eps))
            new_cache["kv"] = _pad_cache(kv, max_len)
        else:
            a, kv = attention.attn_prefill(lp["attn"], xn, cfg, positions)
            x = x + a
            new_cache["kv"] = _pad_cache(kv, max_len)
        if "ln2" in lp:
            xn2 = layers.rms_norm(x, lp["ln2"], cfg.rms_eps)
            if cfg.is_moe:
                y, _ = moe_apply(lp["moe"], xn2, cfg, run.capacity_factor)
                x = x + y
            else:
                m = lp["mlp"]
                x = x + layers.swiglu(xn2, m["w_gate"], m["w_up"], m["w_down"])
        return constrain(x, ACT), new_cache

    policy = run.remat_policy()
    if policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, caches = lax.scan(body, x, layer_params)
    x = layers.rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    logits = unembed(params, cfg, x)[:, 0]
    return logits, caches


def _pad_cache(kv: KVCache, max_len: int) -> KVCache:
    t = kv.k.shape[1]
    pad = ((0, 0), (0, max_len - t), (0, 0), (0, 0))
    return KVCache(jnp.pad(kv.k.astype(jnp.bfloat16), pad),
                   jnp.pad(kv.v.astype(jnp.bfloat16), pad))


def decode_step(params: dict, cfg: ArchConfig, caches: dict,
                tokens: jax.Array, index: jax.Array,
                run: RunConfig = RunConfig()):
    """One-token decode.  tokens: (B, 1); index: scalar current length."""
    x = constrain(embed_tokens(params, cfg, tokens, run.compute_dtype), ACT)
    layer_params = cast_tree(params["layers"], run.compute_dtype)

    def body(x, lp_cache):
        lp, cache = lp_cache
        x, new_cache = _block_decode(cfg, run, lp, x, cache, index)
        return constrain(x, ACT), new_cache

    x, new_caches = lax.scan(body, x, (layer_params, caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = unembed(params, cfg, x)
    return logits, new_caches
