"""Public model API: specs + step functions per (arch, shape).

This is the layer the capsule ("VM image") serializes: everything needed to
instantiate an arch on an arbitrary mesh is derivable from ``ArchConfig`` +
``ShapeConfig`` through these functions — no topology leaks into model code.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import TensorSpec
from repro.models import encdec, lm
from repro.models.layers import softmax_cross_entropy
from repro.models.lm import RunConfig
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def param_specs(cfg: ArchConfig):
    return encdec.encdec_specs(cfg) if cfg.enc_dec else lm.lm_specs(cfg)


def state_specs(cfg: ArchConfig) -> TrainState:
    ps = param_specs(cfg)
    return TrainState(params=ps, opt=adamw.state_specs(ps))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.enc_dec:
        return encdec.encdec_cache_specs(cfg, batch, max_len)
    return lm.cache_specs(cfg, batch, max_len)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct-compatible TensorSpec stand-ins for every input.

    Modality frontends are stubs per the assignment: audio provides
    precomputed frame embeddings; chameleon's VQ ids live in the shared
    vocab so its inputs are ordinary token ids.
    """
    b, t = shape.global_batch, shape.seq_len
    tok = lambda *s: TensorSpec(tuple(s), ("batch",) + (None,) * (len(s) - 1),  # noqa: E731
                                np.int32)
    if shape.kind == "train":
        out = {"tokens": tok(b, t), "labels": tok(b, t)}
        if cfg.enc_dec:
            out["frames"] = TensorSpec((b, t, cfg.d_model),
                                       ("batch", None, "embed"), np.float32)
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok(b, t)}
        if cfg.enc_dec:
            out["frames"] = TensorSpec((b, t, cfg.d_model),
                                       ("batch", None, "embed"), np.float32)
        return out
    # decode: one new token against a cache of length t
    out = {"tokens": tok(b, 1),
           "index": TensorSpec((), (), np.int32)}
    return out


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, run: RunConfig = RunConfig(),
                    opt_cfg: AdamWConfig = AdamWConfig()):
    vocab = cfg.vocab_size

    def loss_fn(params, batch):
        if cfg.enc_dec:
            logits, metrics = encdec.forward_train(
                params, cfg, batch["frames"], batch["tokens"], run)
        else:
            logits, metrics = lm.forward_train(
                params, cfg, batch["tokens"], run)
        loss = softmax_cross_entropy(logits, batch["labels"], vocab)
        if "moe_aux" in metrics:
            loss = loss + cfg.moe.router_aux_coef * metrics["moe_aux"] \
                + 1e-3 * metrics["moe_zloss"]
        return loss, metrics

    def train_step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int,
                      run: RunConfig = RunConfig()):
    def prefill_step(params, batch: dict):
        if cfg.enc_dec:
            return encdec.prefill(params, cfg, batch["frames"],
                                  batch["tokens"], max_len, run)
        return lm.prefill(params, cfg, batch["tokens"], max_len, run)
    return prefill_step


def make_decode_step(cfg: ArchConfig, run: RunConfig = RunConfig()):
    def decode_step(params, caches, batch: dict):
        fn = encdec.decode_step if cfg.enc_dec else lm.decode_step
        logits, new_caches = fn(params, cfg, caches, batch["tokens"],
                                batch["index"], run)
        return logits, new_caches
    return decode_step


def make_eval_loss(cfg: ArchConfig, run: RunConfig = RunConfig()):
    def eval_loss(params, batch: dict):
        if cfg.enc_dec:
            logits, _ = encdec.forward_train(
                params, cfg, batch["frames"], batch["tokens"], run)
        else:
            logits, _ = lm.forward_train(params, cfg, batch["tokens"], run)
        return softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return eval_loss
